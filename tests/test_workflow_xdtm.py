"""SwiftScript DSL semantics + XDTM mappers (dynamic expansion, typing)."""
import os

import numpy as np
import pytest

from repro.core import (CSVMapper, Dataset, Engine, FileSystemMapper, INT,
                        ListMapper, PhysicalRef, ShardMapper, SimClock,
                        STRING, Struct, Workflow)
from repro.core.xdtm import FILE, typecheck


# ---------------------------------------------------------------------------
# mappers
# ---------------------------------------------------------------------------

def test_filesystem_mapper_groups_volume_pairs(tmp_path):
    """The fMRI run_mapper: volume = (.img, .hdr) pair sharing a prefix."""
    for i in range(5):
        (tmp_path / f"bold1_{i:03d}.img").write_text("I")
        (tmp_path / f"bold1_{i:03d}.hdr").write_text("H")
    (tmp_path / "bold1_099.img").write_text("orphan")  # no .hdr -> dropped
    (tmp_path / "other_000.img").write_text("X")
    m = FileSystemMapper(str(tmp_path), "bold1", ("img", "hdr"))
    vols = m.members()
    assert len(vols) == 5
    assert set(vols[0]) == {"img", "hdr"}
    assert all(v["img"].exists() for v in vols)


def test_csv_mapper_montage_table(tmp_path):
    """The Montage overlap table (paper Fig 2) maps to typed records."""
    table = tmp_path / "diffs.tbl"
    table.write_text(
        "cntr1|cntr2|plus|minus|diff\n"
        "0|91|p_a.fits|p_b.fits|diff.000000.000091.fits\n"
        "1|95|p_c.fits|p_d.fits|diff.000001.000095.fits\n")
    DiffStruct = Struct("DiffStruct", (
        ("cntr1", INT), ("cntr2", INT), ("plus", STRING),
        ("minus", STRING), ("diff", STRING)))
    m = CSVMapper(str(table), header=True, hdelim="|", types=DiffStruct)
    recs = m.members()
    assert len(recs) == 2
    assert recs[0]["cntr1"] == 0 and recs[0]["cntr2"] == 91
    assert typecheck(recs[0], DiffStruct)


def test_shard_mapper_roundtrip(tmp_path):
    arr = np.arange(1000, dtype=np.float32).reshape(100, 10)
    m = ShardMapper(str(tmp_path), "w", arr.shape, "float32", n_shards=4)
    refs = m.save(arr)
    assert len(refs) == 4 and all(r.exists() for r in refs)
    np.testing.assert_array_equal(m.load(), arr)


def test_typecheck_primitives():
    assert typecheck(3, INT)
    assert not typecheck("x", INT)
    assert typecheck("x", STRING)
    assert typecheck(PhysicalRef("/tmp/x"), FILE)


# ---------------------------------------------------------------------------
# dynamic workflow expansion (paper §3.6 — the Montage pattern)
# ---------------------------------------------------------------------------

def test_foreach_expands_from_runtime_computed_table(tmp_path):
    """The workflow structure is only determined by a task's OUTPUT at
    runtime: mOverlaps writes a table; foreach maps + iterates it."""
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=4)
    wf = Workflow("montage", eng)
    DiffStruct = Struct("DiffStruct", (("cntr1", INT), ("cntr2", INT)))

    @wf.atomic
    def mOverlaps(n):
        path = os.path.join(tmp_path, "diffs.tbl")
        with open(path, "w") as f:
            f.write("cntr1|cntr2\n")
            for i in range(n):
                f.write(f"{i}|{i + 1}\n")
        return Dataset(CSVMapper(path, header=True, hdelim="|",
                                 types=DiffStruct), "diffs")

    diffs_done = []

    @wf.atomic
    def mDiffFit(rec):
        diffs_done.append((rec["cntr1"], rec["cntr2"]))
        return rec["cntr2"]

    tbl = mOverlaps(7)   # number of rows unknown until runtime
    out = wf.foreach(tbl, lambda rec: mDiffFit(rec))
    wf.run()
    assert out.resolved
    assert len(diffs_done) == 7
    assert out.get() == [i + 1 for i in range(7)]


def test_nested_foreach_and_compound_procedures():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=8)
    wf = Workflow("fmri", eng)

    @wf.atomic
    def reorient(v, direction):
        return (v, direction)

    def reorientRun(run, direction):  # compound procedure
        return wf.foreach(run, lambda v: reorient(v, direction))

    run0 = list(range(6))
    y = reorientRun(run0, "y")
    x = wf.foreach(y, lambda v: reorient(v, "x"))
    wf.run()
    assert x.get() == [((v, "y"), "x") for v in run0]


def test_conditional_execution_on_runtime_data():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site()
    wf = Workflow("cond", eng)

    @wf.atomic
    def count_regions():
        return 12

    @wf.atomic
    def coadd_subregions():
        return "subregions"

    @wf.atomic
    def coadd_direct():
        return "direct"

    n = count_regions()
    big = eng.submit("cmp", lambda x: x > 8, [n])
    out = wf.when(big, lambda: coadd_subregions(), lambda: coadd_direct())
    wf.run()
    assert out.get() == "subregions"


def test_procedure_typechecking():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site()
    wf = Workflow("t", eng)
    p = wf.atomic(lambda a, b: a + len(b), name="p",
                  input_types=(INT, STRING))
    with pytest.raises(TypeError):
        p("not-an-int", "x")
    out = p(3, "ab")
    wf.run()
    assert out.get() == 5


def test_dataset_switching_without_code_change(tmp_path):
    """Paper §3.6: switch a 3-volume test run for a 30-volume production run
    by changing only the mapper inputs."""
    for n, prefix in ((3, "test"), (30, "prod")):
        for i in range(n):
            (tmp_path / f"{prefix}_{i:03d}.img").write_text("I")
            (tmp_path / f"{prefix}_{i:03d}.hdr").write_text("H")

    def run(prefix):
        clock = SimClock()
        eng = Engine(clock)
        eng.local_site(concurrency=8)
        wf = Workflow("fmri", eng)
        proc = wf.atomic(lambda v: 1, name="reorient")
        ds = Dataset(FileSystemMapper(str(tmp_path), prefix))
        out = wf.foreach(ds, lambda v: proc(v))
        wf.run()
        return len(out.get())

    assert run("test") == 3
    assert run("prod") == 30
