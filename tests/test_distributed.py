"""Distributed machinery: elastic rescaling (in a multi-device subprocess),
DRP shrink, vmap-clustering correctness, trainer+compression interplay."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DRPConfig, Engine, FalkonConfig, FalkonService, SimClock
from repro.core.clustering import VmapClusteringProvider
from repro.core.engine import FalkonProvider
from repro.distributed.elastic import ElasticPolicy


def test_elastic_policy_decisions():
    p = ElasticPolicy(min_dp=1, max_dp=16)
    assert p.decide(4, backlog=10.0, step_time=1.0) == 8     # grow
    assert p.decide(4, backlog=0.1, step_time=1.0) == 2      # shrink
    assert p.decide(4, backlog=1.0, step_time=1.0) == 4      # hold
    assert p.decide(16, backlog=100.0, step_time=1.0) == 16  # capped


def test_elastic_reshard_subprocess():
    """Reshard a param tree from a 2-wide to a 4-wide DP mesh (8 fake
    devices) and verify values survive."""
    code = """
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.elastic import make_mesh_for_dp, reshard_tree
from repro.models.params import ParamDesc
descs = {"w": ParamDesc((8, 16), ("batch", None))}
tree = {"w": jnp.arange(128, dtype=jnp.float32).reshape(8, 16)}
m2 = make_mesh_for_dp(2)
t2 = reshard_tree(tree, descs, m2)
m4 = make_mesh_for_dp(4)
t4 = reshard_tree(t2, descs, m4)
np.testing.assert_array_equal(np.asarray(t4["w"]), np.asarray(tree["w"]))
print("OK", t4["w"].sharding)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, cwd="/root/repo")
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_drp_shrinks_idle_executors():
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(drp=DRPConfig(
        max_executors=8, alloc_latency=0.0, idle_timeout=10.0,
        min_executors=1)))
    eng = Engine(clock)
    eng.add_site("f", FalkonProvider(svc), capacity=8)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(16)]
    eng.run()
    assert all(o.resolved for o in outs)
    n_busy_peak = len(svc.executors)
    assert n_busy_peak >= 2
    # after a long idle gap, a single late task's completion triggers the
    # idle-timeout de-registration sweep (paper: idle auto-deregistration)
    late = []
    clock.schedule(100.0, lambda: late.append(
        eng.submit("late", None, duration=1.0)))
    eng.run()
    assert late and late[0].resolved
    assert len(svc.executors) < n_busy_peak  # idles de-registered


def test_vmap_clustering_results_match_per_task():
    eng_c = Engine(SimClock())
    prov = VmapClusteringProvider(eng_c.clock, window=0.0, max_bundle=64)
    eng_c.add_site("d", prov, capacity=64)

    def f(x, w):
        return jnp.tanh(x @ w).sum()

    xs = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (16, 8, 8)))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    outs = [eng_c.submit(f"t{i}", f, [xs[i], w], vmap_key="k")
            for i in range(16)]
    eng_c.run()
    got = np.array([float(o.get()) for o in outs])
    exp = np.array([float(f(jnp.asarray(xs[i]), w)) for i in range(16)])
    np.testing.assert_allclose(got, exp, rtol=1e-5)
    assert prov.bundles_executed == 1  # actually fused


def test_vmap_clustering_mixed_signatures_separate_bundles():
    eng = Engine(SimClock())
    prov = VmapClusteringProvider(eng.clock, window=0.0, max_bundle=64)
    eng.add_site("d", prov, capacity=64)

    def f(x):
        return x * 2

    a = [eng.submit(f"a{i}", f, [jnp.ones((4,))], vmap_key="a")
         for i in range(4)]
    b = [eng.submit(f"b{i}", f, [jnp.ones((8,))], vmap_key="b")
         for i in range(4)]
    eng.run()
    assert all(o.resolved for o in a + b)
    assert prov.bundles_executed == 2  # one bundle per signature


def test_grad_compression_in_training_loop():
    """Simulated cross-pod sync: train with error-feedback int8-compressed
    gradients and verify the loss still decreases on a quadratic."""
    from repro.optim import adamw, compression
    hp = adamw.Hyper(lr=0.05, warmup=0, weight_decay=0.0, clip=1e9,
                     total_steps=300, min_lr_frac=1.0)
    params = {"w": jnp.array([4.0, -2.0, 7.0])}
    opt = adamw.init(params)
    target = jnp.array([1.0, 2.0, 3.0])
    residual = compression.init_residual(params)
    for step in range(300):
        grads = {"w": params["w"] - target}
        _, residual, grads = compression.compress_with_feedback(
            grads, residual, scheme="int8")
        params, opt = adamw.update(grads, opt, params, jnp.asarray(step), hp)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)
