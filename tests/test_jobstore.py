"""Job-store tests (DESIGN.md §15): the status state machine, the journal,
and sqlite durability.

The property tests (hypothesis, or the deterministic shim from conftest)
drive the invariants the recovery layer rests on:

  * arbitrary interleavings of record attempts never leave the state
    machine in a state it did not admit — every accepted transition is in
    the declared relation, every rejected one raises `IllegalTransition`
    and leaves the state untouched;
  * replaying any *prefix* of a journal yields a consistent resumable
    frontier: done ∪ failed ∪ frontier partitions the keys, and a key is
    restorable iff its DONE row made the prefix.
"""
import json
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Engine, IllegalTransition, JobStore, RestartLog,
                        SimClock, TaskStateMachine)
from repro.core.jobstore import (DISPATCHED, DONE, FAILED, READY, REVOKED,
                                 STATUS_NAMES, SUBMITTED, TERMINAL, _NEXT)
from repro.core.xdtm import PhysicalRef

# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------


def test_happy_path_and_terminal_states():
    sm = TaskStateMachine()
    for s in (SUBMITTED, READY, DISPATCHED, DONE):
        assert sm.advance("k", s)
    assert sm.state["k"] == DONE
    for s in (SUBMITTED, READY, DISPATCHED, DONE, FAILED, REVOKED):
        with pytest.raises(IllegalTransition):
            sm.advance("k", s)


def test_retry_and_revoke_loops():
    sm = TaskStateMachine()
    sm.advance("k", SUBMITTED)
    sm.advance("k", READY)
    sm.advance("k", DISPATCHED)
    sm.advance("k", REVOKED)     # drain revocation
    sm.advance("k", READY)       # re-placed
    sm.advance("k", DISPATCHED)
    sm.advance("k", READY)       # retry after failure
    sm.advance("k", DISPATCHED)
    sm.advance("k", FAILED)
    assert sm.state["k"] == FAILED


def test_idempotent_self_loops_counted_not_raised():
    sm = TaskStateMachine()
    sm.advance("k", SUBMITTED)
    assert sm.advance("k", SUBMITTED) is False   # duplicate content key
    sm.advance("k", READY)
    assert sm.advance("k", READY) is False       # steal re-dispatch
    assert sm.duplicates == 2
    with pytest.raises(IllegalTransition):
        sm.advance("k2", READY)                  # must start at submitted


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(
    [(k, s) for k in ("a", "b", "c") for s in range(6)]),
    min_size=0, max_size=60))
def test_property_no_interleaving_admits_illegal_transition(ops):
    """Fuzz record attempts over a few keys: the machine's visible state
    only ever moves along the declared relation, and a rejected attempt
    changes nothing."""
    sm = TaskStateMachine()
    shadow: dict = {}
    for key, status in ops:
        cur = shadow.get(key)
        legal = status in _NEXT[cur] or (cur == status
                                         and status in (SUBMITTED, READY))
        if legal:
            sm.advance(key, status)
            if cur != status:
                shadow[key] = status
        else:
            before = dict(sm.state)
            with pytest.raises(IllegalTransition):
                sm.advance(key, status)
            assert sm.state == before
    assert sm.state == shadow


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=40),
       st.integers(0, 39))
def test_property_journal_prefix_replay_is_consistent_frontier(seq, cut):
    """Build a legal journal for a set of keys by walking random legal
    steps, then replay an arbitrary prefix into a fresh machine: the
    replay must accept every row, and done/failed/frontier partition the
    replayed keys exactly by their last row in the prefix."""
    import random
    rng = random.Random(sum(seq) * 31 + cut)
    journal: list = []
    live: dict = {}
    for i, _ in enumerate(seq):
        key = f"k{i % 7}"
        cur = live.get(key)
        nxt = sorted(_NEXT[cur])
        if not nxt:
            continue
        status = nxt[rng.randrange(len(nxt))]
        journal.append((key, status))
        live[key] = status
    prefix = journal[:cut % (len(journal) + 1)]
    sm = TaskStateMachine()
    last: dict = {}
    for key, status in prefix:
        sm.advance(key, status)      # replay never raises on a real journal
        last[key] = status
    done = {k for k, s in last.items() if s == DONE}
    failed = {k for k, s in last.items() if s == FAILED}
    frontier = set(sm.frontier())
    assert done | failed | frontier == set(last)
    assert not (done & frontier) and not (failed & frontier)
    assert frontier == {k for k, s in last.items() if s not in TERMINAL}


# ---------------------------------------------------------------------------
# journal + store
# ---------------------------------------------------------------------------


def _drive(journal, key, value=None, fail=None):
    journal.task_submitted(key)
    journal.task_ready(key)
    journal.task_dispatched(key)
    if fail is not None:
        journal.task_failed(key, fail)
    else:
        journal.task_done(key, value)


def test_store_round_trip_and_peek(tmp_path):
    db = str(tmp_path / "t.db")
    with JobStore(db, flush_interval=0.01) as store:
        j = store.journal(default_wf="wf", batch=4)
        for i in range(9):
            _drive(j, f"wf::k{i}", value={"i": i})
        _drive(j, "wf::bad", fail="boom")
        j.flush()
        store.sync()
        state = store.load("wf")
        assert len(state.done) == 9 and state.done["wf::k3"] == {"i": 3}
        assert state.failed == {"wf::bad": "boom"}
        assert state.run_id == 1
        counts = JobStore.peek(db, "wf")
        assert counts["done"] == 9 and counts["failed"] == 1
    # a fresh store over the same file sees the same durable state
    with JobStore(db) as store2:
        assert len(store2.load("wf").done) == 9
        assert store2.begin_run("wf") == 2   # attempts accumulate


def test_durability_modes_split_journal_table(tmp_path):
    """Terminal durability persists into the tasks upsert only (the
    journal audit table would duplicate it); full durability records
    every transition there too."""
    with JobStore(str(tmp_path / "t.db")) as store:
        jt = store.journal(default_wf="a")
        _drive(jt, "a::k", value=1)
        jf = store.journal(default_wf="b", durability="full")
        _drive(jf, "b::k", value=1)
        jt.flush(); jf.flush(); store.sync()
        assert store.journal_rows("a") == []
        assert [s for _, _, s in store.journal_rows("b")] == \
            [SUBMITTED, READY, DISPATCHED, DONE]
        # both modes reach the same durable resume state
        assert store.load("a").done == {"a::k": 1}
        assert store.load("b").done == {"b::k": 1}


def test_non_json_values_degrade_to_rerun(tmp_path):
    """A DONE row whose value cannot be encoded is persisted value-less:
    the task is *not* restorable and re-runs on resume."""
    with JobStore(str(tmp_path / "t.db")) as store:
        j = store.journal(default_wf="w")
        _drive(j, "w::opaque", value=object())
        _drive(j, "w::plain", value=7)
        j.flush(); store.sync()
        state = store.load("w")
        assert "w::opaque" not in state.done and state.done["w::plain"] == 7
        assert state.counts["done"] == 2   # durably done, just not resumable


def test_physical_refs_round_trip_and_existence_gate(tmp_path):
    art = tmp_path / "artifact.bin"
    art.write_bytes(b"x")
    with JobStore(str(tmp_path / "t.db")) as store:
        j = store.journal(default_wf="w")
        _drive(j, "w::a", value=PhysicalRef(str(art)))
        j.flush(); store.sync()
        state = store.load("w")
        assert isinstance(state.done["w::a"], PhysicalRef)
        os.unlink(art)
        state2 = store.load("w")
        assert "w::a" not in state2.done   # artifact gone -> re-run


def test_unique_key_occurrence_suffixes():
    with JobStore(":memory:") as store:
        j = store.journal()
        assert j.unique_key("k") == "k"
        assert j.unique_key("k") == "k~1"
        assert j.unique_key("k") == "k~2"
        assert j.unique_key("other") == "other"


def test_import_restart_log(tmp_path):
    rlog = RestartLog(str(tmp_path / "r.rlog"))
    rlog.append("stage-a", [1, 2])
    rlog.append("stage-b", {"x": 3})
    with JobStore(str(tmp_path / "t.db")) as store:
        assert store.import_restart_log(rlog, wf_id="legacy") == 2
        state = store.load("legacy")
        assert state.done == {"legacy::stage-a": [1, 2],
                              "legacy::stage-b": {"x": 3}}


def test_engine_journal_hooks_record_lifecycle(tmp_path):
    """A journaled engine run records the full state machine for every
    task — including retries and terminal failures — with no explicit
    keys passed."""
    from repro.core import FaultInjector, RetryPolicy
    clock = SimClock()
    inj = FaultInjector().fail_first_n("flaky", 1)
    eng = Engine(clock, fault_injector=inj,
                 retry_policy=RetryPolicy(max_retries=1, backoff=0.0))
    eng.local_site(concurrency=2)
    with JobStore(str(tmp_path / "t.db")) as store:
        eng.journal = j = store.journal(default_wf="", durability="full")
        a = eng.submit("ok", None, duration=0.01)
        b = eng.submit("flaky", None, args=[a], duration=0.01)
        c = eng.submit("doomed", int, args=["nope"], duration=0.01)
        eng.run()
        j.flush(); store.sync()
        assert a.resolved and b.resolved and c.failed
        state = store.load("")
        assert state.counts["done"] == 2 and state.counts["failed"] == 1
        # the flaky task's journal shows the retry loop
        rows = [(k, s) for _, k, s in store.journal_rows("")
                if k.startswith("flaky")]
        statuses = [s for _, s in rows]
        assert statuses.count(DISPATCHED) == 2   # first attempt + retry
        assert statuses[-1] == DONE


def test_sigkill_mid_write_leaves_readable_store(tmp_path):
    """SIGKILL the owning process between commits: the WAL database stays
    readable and holds exactly the committed prefix."""
    import signal
    import subprocess
    import sys
    import time as _time
    db = str(tmp_path / "kill.db")
    code = (
        "import sys, time; sys.path.insert(0, %r)\n"
        "from repro.core import JobStore\n"
        "store = JobStore(%r, flush_interval=0.005)\n"
        "j = store.journal(default_wf='w', batch=1)\n"
        "i = 0\n"
        "while True:\n"
        "    k = f'w::k{i}'\n"
        "    j.task_submitted(k); j.task_ready(k)\n"
        "    j.task_dispatched(k); j.task_done(k, i)\n"
        "    j.flush(); i += 1; time.sleep(0.001)\n"
        % (os.path.join(os.path.dirname(__file__), "..", "src"), db))
    proc = subprocess.Popen([sys.executable, "-c", code])
    try:
        deadline = _time.monotonic() + 30.0
        while _time.monotonic() < deadline:
            try:
                if JobStore.peek(db, "w")["done"] >= 20:
                    break
            except Exception:
                pass
            _time.sleep(0.02)
        else:
            pytest.fail("child made no observable progress")
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait()
    with JobStore(db) as store:
        state = store.load("w")
        assert len(state.done) >= 20
        # committed prefix is dense: every key below the max is present
        idx = sorted(int(k.split("k")[-1]) for k in state.done)
        assert idx == list(range(len(idx)))
