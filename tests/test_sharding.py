"""Sharding-rule resolution: divisibility fallback, ZeRO extension, and
property tests over arbitrary shapes."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs import registry
from repro.launch.specs import axis_rules_for, opt_rule_extend
from repro.models.params import ParamDesc, default_rules, resolve_spec

MESH = {"data": 16, "model": 16}


def test_divisible_axes_shard():
    d = ParamDesc((1024, 2816), ("embed", "ff"))
    spec = resolve_spec(d, default_rules(), MESH)
    assert spec == P(None, "model")


def test_indivisible_axes_fall_back_to_replication():
    # 28 heads on a 16-way model axis -> replicated
    d = ParamDesc((3584, 28, 128), ("embed", "heads", "head_dim"))
    spec = resolve_spec(d, default_rules(), MESH)
    assert spec == P()


def test_axis_used_once_per_tensor():
    # experts->data and batch->data cannot both apply to one tensor
    d = ParamDesc((16, 160, 64), ("batch", "experts", None))
    spec = resolve_spec(d, default_rules(), MESH)
    assert spec == P("data")  # first dim grabs it; second falls back


def test_opt_rule_extend_adds_data_axis():
    d = ParamDesc((5376, 21504), ("embed", "ff"))
    spec = resolve_spec(d, default_rules(), MESH)
    ext = opt_rule_extend(spec, d.shape, MESH, "data")
    assert ext == P("data", "model")


def test_opt_rule_extend_noop_when_data_used():
    spec = P("data", "model")
    ext = opt_rule_extend(spec, (160, 1536), MESH, "data")
    assert ext == spec


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    axes=st.lists(st.sampled_from(
        ["embed", "ff", "heads", "vocab", "batch", "experts", None]),
        min_size=1, max_size=4),
)
def test_resolve_spec_properties(dims, axes):
    """Properties: (1) every sharded dim is divisible by its axis size;
    (2) no mesh axis is used twice; (3) spec length <= rank."""
    n = min(len(dims), len(axes))
    d = ParamDesc(tuple(dims[:n]), tuple(axes[:n]))
    spec = resolve_spec(d, default_rules(), MESH)
    assert len(spec) <= n
    used = []
    for dim, part in zip(d.shape, tuple(spec) + (None,) * (n - len(spec))):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        size = 1
        for nm in names:
            size *= MESH[nm]
        assert dim % size == 0
        used.extend(names)
    assert len(used) == len(set(used))


@pytest.mark.parametrize("shape", list(SHAPES))
def test_axis_rules_per_cell(shape):
    cfg = registry.get_config("gemma3-27b")
    mesh = type("M", (), {"axis_names": ("data", "model"),
                          "devices": type("D", (), {"shape": (16, 16)})()})()
    rules = axis_rules_for(cfg, SHAPES[shape], mesh)
    cell = SHAPES[shape]
    if cell.kind in ("train", "prefill"):
        assert rules["seq_act"] == "model"
    if cell.kind == "decode":
        assert rules["seq_act"] is None
        if shape == "long_500k":
            assert rules["kv_seq"] == "data"
        else:
            assert rules["kv_seq"] == "model"
