"""Unit tests for the unified observability layer (DESIGN.md §12):

  * BoundedLog — exact counts, cap bound, deterministic decimation;
  * Tracer — deterministic span streams across identical SimClock runs,
    sampling bounds at 10^5 tasks, exact critical path on known DAGs;
  * Chrome trace export — schema-checked with tools/trace_view.py;
  * federation — one shared tracer across shards, per-shard-consistent
    and replay-identical traces, mailbox flush events;
  * provenance — span ids on InvocationRecords, VDC export_jsonl /
    load_jsonl round-trip;
  * StreamStat min + reservoir percentiles; MetricsRegistry; RunReport.
"""
import json
import os
import sys

import pytest

from repro.core import (BoundedLog, Engine, FalkonConfig, DRPConfig,
                        FalkonProvider, FalkonService, FederatedEngine,
                        LocalProvider, MetricsRegistry, SimClock,
                        StreamStat, Tracer, VDC, Workflow, build_report)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
from tools.trace_view import main as trace_view_main  # noqa: E402
from tools.trace_view import validate_chrome_trace  # noqa: E402


# ---------------------------------------------------------------------------
# BoundedLog
# ---------------------------------------------------------------------------

def test_bounded_log_exact_count_and_cap():
    lg = BoundedLog(cap=64)
    for i in range(10_000):
        lg.append(i)
    assert lg.count == 10_000
    assert len(lg) < 64
    assert lg.stride > 1
    assert lg[0] == 0                   # first entry stays anchored
    kept = list(lg)
    assert kept == sorted(kept)         # append order preserved


def test_bounded_log_decimation_is_deterministic():
    a, b = BoundedLog(cap=32), BoundedLog(cap=32)
    for i in range(5_000):
        a.append(i)
        b.append(i)
    assert a == b and list(a) == list(b)


def test_bounded_log_compares_to_plain_lists():
    lg = BoundedLog(cap=16)
    assert lg == []
    lg.append("x")
    assert lg == ["x"] and lg != []


def test_bounded_log_small_caps_rejected():
    with pytest.raises(ValueError):
        BoundedLog(cap=1)


# ---------------------------------------------------------------------------
# Tracer core: determinism, sampling bounds, critical path
# ---------------------------------------------------------------------------

def _run_traced_fmri(volumes=12, sample_every=1, max_spans=4096):
    clock = SimClock()
    tracer = Tracer(sample_every=sample_every, max_spans=max_spans)
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=8, alloc_latency=2.0, alloc_chunk=4)),
        trace=True, tracer=tracer)
    eng = Engine(clock, tracer=tracer)
    eng.add_site("falkon", FalkonProvider(svc), capacity=8)
    wf = Workflow("fmri", eng)
    stages = [("reorient", 3.0), ("align", 6.0), ("reslice", 4.0)]
    outs = []
    for v in range(volumes):
        f = None
        for name, dur in stages:
            f = eng.submit(name, None, [f] if f is not None else [],
                           duration=dur)
        outs.append(f)
    out = wf.gather(outs)
    wf.run()
    assert out.resolved
    return clock, tracer, eng


def test_identical_runs_produce_identical_span_streams():
    _, tr1, _ = _run_traced_fmri()
    _, tr2, _ = _run_traced_fmri()
    assert [sp.to_dict() for sp in tr1.spans] == \
        [sp.to_dict() for sp in tr2.spans]
    assert tr1.snapshot() == tr2.snapshot()
    # the exported artifacts are byte-identical too (no RNG, no wall
    # reads, insertion-ordered dicts)
    assert json.dumps(tr1.export_chrome_trace(), sort_keys=True) == \
        json.dumps(tr2.export_chrome_trace(), sort_keys=True)


def test_sampling_keeps_memory_bounded_at_1e5_tasks():
    n = 100_000
    clock = SimClock()
    tracer = Tracer(sample_every=4, max_spans=512, event_cap=128,
                    log_cap=256)
    eng = Engine(clock, tracer=tracer, provenance="summary")
    eng.local_site(concurrency=64)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(n)]
    eng.run()
    assert all(o.resolved for o in outs)
    # exact counters cover every task; the span store stays capped
    assert tracer.tasks_seen == n and tracer.tasks_done == n
    assert len(tracer.spans) <= 512
    snap = tracer.snapshot()
    assert snap["sample_stride"] > 4   # the span store decimated en route
    # closed-span weight coverage is exact: every 4th task carried a span
    # of weight 4, and store decimation never loses the total
    assert tracer.span_weight_total == pytest.approx(n)
    # a dependency-free task is ready at submission, so its path includes
    # the site-queue wait: the last task's path IS the makespan here
    assert tracer.critical_path_s == pytest.approx(clock.now())


def test_critical_path_exact_on_diamond_dag():
    clock = SimClock()
    tracer = Tracer()
    eng = Engine(clock, tracer=tracer)
    eng.local_site(concurrency=4)
    a = eng.submit("a", None, duration=2.0)
    b = eng.submit("b", None, [a], duration=3.0)
    c = eng.submit("c", None, [a], duration=7.0)
    d = eng.submit("d", None, [b, c], duration=5.0)
    eng.run()
    assert d.resolved
    # a -> c -> d is the long chain: 2 + 7 + 5
    assert tracer.critical_path_s == pytest.approx(14.0)
    rep = build_report(tracer, makespan=clock.now()).to_dict()
    assert rep["critical_path_s"] == pytest.approx(14.0)
    assert rep["critical_path_ratio"] == pytest.approx(1.0)


def test_retries_and_failures_are_counted():
    from repro.core.faults import FaultInjector, RetryPolicy
    clock = SimClock()
    tracer = Tracer()
    eng = Engine(clock, tracer=tracer,
                 retry_policy=RetryPolicy(max_retries=3),
                 fault_injector=FaultInjector().fail_first_n("flaky", 2))
    eng.local_site(concurrency=2)
    ok = eng.submit("solid", lambda: "ok")
    fl = eng.submit("flaky", lambda: "ok")
    eng.run()
    assert ok.resolved and fl.resolved
    assert tracer.tasks_done == 2
    assert tracer.tasks_retried == 2
    assert tracer.tasks_failed == 0
    # the surviving span reports the final attempt number
    flaky_spans = [sp for sp in tracer.spans if sp.name == "flaky"]
    assert flaky_spans and flaky_spans[0].attempt == 2
    assert flaky_spans[0].status == "ok"


def test_terminal_failure_closes_span_as_failed():
    from repro.core.faults import FaultInjector, RetryPolicy
    clock = SimClock()
    tracer = Tracer()
    eng = Engine(clock, tracer=tracer,
                 retry_policy=RetryPolicy(max_retries=1),
                 fault_injector=FaultInjector().fail_first_n("doomed", 10))
    eng.local_site(concurrency=1)
    out = eng.submit("doomed", lambda: "ok")
    eng.run()
    assert out.failed
    assert tracer.tasks_failed == 1 and tracer.tasks_done == 0
    assert tracer.tasks_retried == 1
    sp = tracer.spans[0]
    assert sp.status == "failed"


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_is_schema_valid_and_loadable(tmp_path):
    _, tracer, _ = _run_traced_fmri()
    path = str(tmp_path / "trace.json")
    trace = tracer.export_chrome_trace(path)
    assert validate_chrome_trace(trace) == []
    with open(path, encoding="utf-8") as f:
        reloaded = json.load(f)
    assert validate_chrome_trace(reloaded) == []
    events = reloaded["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "X", "C"} <= phases     # metadata, spans, counters
    # metadata events sort ahead of data so viewers name tracks up front
    first_data = next(i for i, e in enumerate(events) if e["ph"] != "M")
    assert all(e["ph"] != "M" for e in events[first_data:])
    # lifecycle spans carry their span ids and status
    xs = [e for e in events if e["ph"] == "X" and e.get("cat") == "task"]
    assert xs and all(e["args"]["status"] == "ok" for e in xs)
    assert reloaded["otherData"]["schema"] == "repro.chrome_trace/v1"
    # the CLI validates and summarizes it, exit 0
    assert trace_view_main([path, "--validate"]) == 0
    assert trace_view_main([path]) == 0


def test_trace_view_rejects_malformed_artifacts(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X", "pid": "oops"}]}))
    assert trace_view_main([str(bad)]) == 1
    unknown = tmp_path / "unknown.json"
    unknown.write_text(json.dumps({"schema": "something/else"}))
    assert trace_view_main([str(unknown)]) == 1
    capsys.readouterr()


def test_run_report_renders_via_trace_view(tmp_path):
    clock, tracer, _ = _run_traced_fmri()
    rep = build_report(tracer, makespan=clock.now())
    path = str(tmp_path / "report.json")
    rep.to_json(path)
    assert trace_view_main([path, "--validate"]) == 0
    assert trace_view_main([path]) == 0


# ---------------------------------------------------------------------------
# federation: shared tracer, per-shard consistency, replay determinism
# ---------------------------------------------------------------------------

def _run_traced_federation(n_shards=2, chains=40, length=4):
    clock = SimClock()
    tracer = Tracer()
    fed = FederatedEngine(n_shards, clock=clock, tracer=tracer,
                          delivery_latency=0.5,
                          engine_kwargs={"provenance": "summary"})
    for i, eng in enumerate(fed.shards):
        eng.add_site(f"local{i}", LocalProvider(clock, concurrency=8),
                     capacity=8)
    wf = Workflow("fed", fed)
    outs = []
    for c in range(chains):
        f = None
        for s in range(length):
            f = fed.submit(f"stage{s}", None,
                           [f] if f is not None else [], duration=1.0)
        outs.append(f)
    out = wf.gather(outs)
    wf.run()
    assert out.resolved
    return clock, tracer, fed


def test_federated_runs_share_one_consistent_tracer():
    _, tracer, fed = _run_traced_federation()
    n = sum(e.tasks_completed for e in fed.shards)
    assert tracer.tasks_seen == n and tracer.tasks_done == n
    # every span belongs to a real shard, and under the default hash
    # partitioner no shard is silent
    shards = {sp.shard for sp in tracer.spans}
    assert shards <= set(range(len(fed.shards))) and len(shards) > 1
    # cross-shard proxies flow through mailboxes, which trace their flushes
    if fed.cross_shard_edges:
        assert tracer.event_counts()["mailbox_flush"]["count"] > 0
    # chrome export splits tracks per shard
    trace = tracer.export_chrome_trace()
    assert validate_chrome_trace(trace) == []
    procs = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {f"shard{s}" for s in shards} <= procs


def test_federated_traces_replay_identically():
    _, tr1, _ = _run_traced_federation()
    _, tr2, _ = _run_traced_federation()
    assert [sp.to_dict() for sp in tr1.spans] == \
        [sp.to_dict() for sp in tr2.spans]
    assert tr1.snapshot() == tr2.snapshot()


# ---------------------------------------------------------------------------
# provenance: span ids + export/reload round-trip
# ---------------------------------------------------------------------------

def test_invocation_records_carry_span_ids_and_roundtrip(tmp_path):
    clock = SimClock()
    tracer = Tracer(sample_every=2)
    eng = Engine(clock, tracer=tracer, provenance="records")
    eng.local_site(concurrency=4)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(10)]
    eng.run()
    assert all(o.resolved for o in outs)
    recs = eng.vdc.records
    assert len(recs) == 10
    stamped = [r for r in recs if r.span_id]
    assert len(stamped) == 5            # every 2nd submitted task sampled
    span_ids = {sp.span_id for sp in tracer.spans}
    assert {r.span_id for r in stamped} == span_ids

    path = str(tmp_path / "vdc.jsonl")
    n = eng.vdc.export_jsonl(path)
    assert n == 10
    vdc2 = VDC.load_jsonl(path)
    assert len(vdc2.records) == 10
    assert vdc2.summary() == eng.vdc.summary()
    assert [r.span_id for r in vdc2.records] == [r.span_id for r in recs]


# ---------------------------------------------------------------------------
# StreamStat min + percentiles
# ---------------------------------------------------------------------------

def test_stream_stat_min_and_percentiles_exact_when_unsampled():
    s = StreamStat(cap=1024)
    vals = [float(v) for v in (5, 1, 9, 3, 7, 2, 8, 4, 6, 10)]
    for i, v in enumerate(vals):
        s.observe(float(i), v)
    summ = s.summary()
    assert summ["min"] == 1.0 and summ["peak"] == 10.0
    assert summ["p50"] == 5.0
    assert summ["p95"] == summ["p99"] == 10.0
    assert s.percentile(0.5) == 5.0


def test_stream_stat_percentiles_bounded_under_decimation():
    s = StreamStat(cap=32)
    n = 50_000
    for i in range(n):
        s.observe(float(i), float(i % 1000))
    summ = s.summary()
    assert summ["min"] == 0.0 and summ["peak"] == 999.0
    assert 0.0 <= summ["p50"] <= 999.0
    assert summ["p50"] <= summ["p95"] <= summ["p99"] <= summ["peak"]


# ---------------------------------------------------------------------------
# MetricsRegistry + RunReport
# ---------------------------------------------------------------------------

def test_metrics_registry_normalizes_sources():
    reg = MetricsRegistry()
    st = StreamStat()
    st.observe(0.0, 3.0)
    reg.register("stat", st)
    reg.register("plain", {"k": 1})
    reg.register("fn", lambda: {"v": 2})
    snap = reg.snapshot()
    assert snap["stat"]["count"] == 1 and snap["stat"]["min"] == 3.0
    assert snap["plain"] == {"k": 1} and snap["fn"] == {"v": 2}
    json.dumps(snap)                     # JSON-able end to end
    with pytest.raises(ValueError):
        reg.register("stat", st)


def test_run_report_schema_and_breakdown():
    clock, tracer, eng = _run_traced_fmri(volumes=10)
    reg = MetricsRegistry()
    reg.register("engine", eng)
    rep = build_report(tracer, reg, makespan=clock.now())
    p = rep.to_dict()
    assert p["schema"] == "repro.run_report/v1"
    assert p["tasks"]["done"] == tracer.tasks_done
    assert set(p["stages"]) == {"reorient", "align", "reslice"}
    # full sampling, no decimation: per-stage totals are exact
    assert p["stages"]["align"]["count_est"] == 10
    assert p["stages"]["align"]["run_s_est"] == pytest.approx(60.0)
    assert 0.0 < p["critical_path_ratio"] <= 1.0 + 1e-9
    for key in ("queue_wait_s", "stage_wait_s", "run_s"):
        blk = p["percentiles"][key]
        assert blk["min"] <= blk["p50"] <= blk["p95"] <= blk["max"]
    util = p["utilization"]["sites"]
    assert "falkon" in util and max(util["falkon"]) > 0
    assert "engine" in p["components"]
    text = rep.format()
    assert "critical path" in text and "align" in text


def test_falkon_trace_logs_ride_the_tracer():
    """`FalkonService(trace=True)` without an explicit tracer self-hosts
    one: the legacy log attributes stay usable but are bounded now."""
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=4, alloc_latency=1.0, alloc_chunk=2)),
        trace=True)
    assert svc.tracer is not None
    eng = Engine(clock)
    eng.add_site("f", FalkonProvider(svc), capacity=4)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(50)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert isinstance(svc.queue_len_log, BoundedLog)
    assert svc.queue_len_log.count == svc.queue_stat.count
    assert len(svc.tracer.exec_spans) > 0
    assert svc.tracer.event_counts()["drp_alloc"]["count"] >= 1
