"""Process-per-shard federation tests (DESIGN.md §14).

Each test boots real OS processes (one Engine + RealClock + worker pool
per shard), so shard counts and task counts stay small:

  * end-to-end dataflow across 2 process-shards, including cross-process
    dependency chains resolved through `Ref` envelopes;
  * failure propagation: an upstream exception crossing the pipe fails
    the downstream consumer with the original error;
  * parent-coordinated work stealing moving tasks off a loaded shard;
  * shard-crash handling: with the default retry budget, in-flight tasks
    fail over to surviving shards (driver-side re-submission through the
    retained submit context); with `RetryPolicy(max_retries=0)` they
    fail fast with `TaskFailure(kind="host")` — either way a
    `shard_death` tracer event fires and `run()` returns instead of
    hanging;
  * the socket-framed transport as a drop-in for the pipe transport;
  * sim-vs-real equivalence: a MolDyn-shaped DAG produces identical
    values and identical per-shard placement under `FederatedEngine`
    (SimClock, in-process) and `ProcessFederation` (RealClock, 2 procs).
"""
import time

import pytest

from repro.core import (DRPConfig, FalkonConfig, FalkonProvider,
                        FalkonService, FederatedEngine, ProcessFederation,
                        RetryPolicy, ShardSpec, SimClock, TaskFailure,
                        hash_partitioner)
from repro.core.procfed import body_scale, body_sleep, body_sum, body_value

SPEC = ShardSpec(executors=2, alloc_latency=1e-4)


def _moldyn_submit(fed, n_mol=4, n_an=3):
    """MolDyn-shaped DAG: per molecule, one generator fans out to `n_an`
    analyses which gather into one collect."""
    cols = {}
    for m in range(n_mol):
        gen = fed.submit("gen", body_value, [m * 10], duration=0.02,
                         key=f"gen_m{m}")
        ans = [fed.submit("an", body_scale, [gen], duration=0.01,
                          key=f"an_m{m}_k{k}") for k in range(n_an)]
        cols[m] = fed.submit("col", body_sum, ans, duration=0.01,
                             key=f"col_m{m}")
    return cols


def test_two_shard_end_to_end_with_cross_shard_deps():
    """Dependency chains whose edges cross the process boundary resolve
    to correct values, and the driver aggregates stats/metrics/report."""
    with ProcessFederation(2, SPEC, steal=False) as fed:
        fed.wait_ready()
        cols = _moldyn_submit(fed)
        fed.run()
        for m, fut in cols.items():
            assert fut.resolved and fut.get() == 3 * (2 * m * 10)
        stats = fed.stats()
        assert stats["completed"] == 20 and stats["failed"] == 0
        assert sum(stats["per_shard_completed"]) == 20
        assert stats["cross_shard_edges"] > 0   # hash split the chains
        fed.shutdown()                          # collect child telemetry
        m = fed.metrics()
        assert m["pool"]["tasks_run"] == 20     # merged child pool stats
        assert m["dead_shards"] == []
        rep = fed.report()
        assert rep["makespan_s"] > 0.0


def test_failure_propagates_across_processes():
    """An upstream exception on shard 0 fails its shard-1 consumer with
    the original error, shipped through a resolve envelope."""
    part = lambda key, n: 0 if key.startswith("boom") else 1
    with ProcessFederation(2, SPEC, partitioner=part, steal=False) as fed:
        fed.wait_ready()
        bad = fed.submit("boom", int, ["nope"], key="boom#0")
        child = fed.submit("child", body_scale, [bad], key="child#0")
        fed.run()
        assert bad.failed and child.failed
        with pytest.raises(ValueError):
            bad.get()
        assert fed.tasks_failed == 2


def test_steal_rebalances_all_on_one_shard():
    """Every task partitioned to shard 0; the parent-coordinated stealer
    must move work to the idle shard and finish everything."""
    with ProcessFederation(2, SPEC, partitioner=lambda k, n: 0,
                           steal=True, min_batch=1) as fed:
        fed.wait_ready()
        futs = [fed.submit("t", body_sleep, [0.01], key=f"t#{i}")
                for i in range(40)]
        fed.run()
        assert all(f.resolved for f in futs)
        assert fed.tasks_stolen > 0
        per_shard = fed.stats()["per_shard_completed"]
        assert per_shard[1] > 0 and sum(per_shard) == 40


def test_shard_crash_fails_inflight_futures():
    """With `max_retries=0` (fail-fast), killing a shard process mid-run
    fails its in-flight futures with `TaskFailure(kind="host")` and a
    `shard_death` tracer event — the driver's `run()` returns instead of
    hanging."""
    part = lambda key, n: int(key.split("#")[1]) % n
    with ProcessFederation(2, SPEC, partitioner=part, steal=False,
                           retry_policy=RetryPolicy(max_retries=0)) as fed:
        fed.wait_ready()
        futs = [fed.submit("t", body_sleep, [0.5], key=f"t#{i}")
                for i in range(8)]
        fed._procs[1].kill()
        t0 = time.monotonic()
        fed.run()
        assert time.monotonic() - t0 < 10.0
        dead = [f for i, f in enumerate(futs) if i % 2 == 1]
        live = [f for i, f in enumerate(futs) if i % 2 == 0]
        assert all(f.failed for f in dead)
        for f in dead:
            with pytest.raises(TaskFailure) as ei:
                f.get()
            assert ei.value.kind == "host"
        assert all(f.resolved for f in live)
        assert fed.tracer.event_counts()["shard_death"]["count"] == 1
        assert fed.metrics()["dead_shards"] == [1]
        assert fed.tasks_failed_over == 0


def test_shard_crash_fails_over_to_survivor():
    """With the default retry budget, tasks lost to a dead shard are
    re-submitted to the surviving shard through the retained submit
    context — every future still resolves, including a dependency chain
    whose upstream died in flight (the ISSUE-10 fix for PR 9's fail-fast
    gap)."""
    part = lambda key, n: 0 if key.startswith("on0") else 1
    with ProcessFederation(2, SPEC, partitioner=part, steal=False) as fed:
        fed.wait_ready()
        # shard 1 holds the sleepers; shard 0 holds a consumer chained on
        # one of them, so failover must also carry the Ref edge
        futs = [fed.submit("on1", body_sleep, [0.4], key=f"on1#{i}")
                for i in range(4)]
        base = fed.submit("on1v", body_sleep, [0.42], key="on1v#0")
        chained = fed.submit("on0c", body_scale, [base], key="on0c#0")
        fed._procs[1].kill()
        t0 = time.monotonic()
        fed.run()
        assert time.monotonic() - t0 < 30.0
        assert all(f.resolved for f in futs)
        assert base.resolved and chained.resolved
        assert chained.get() == 0.84
        assert fed.tasks_failed_over >= 1
        assert fed.tasks_failed == 0
        assert fed.tracer.event_counts()["shard_death"]["count"] == 1
        assert fed.tracer.event_counts()["task_failover"]["count"] == 1
        assert fed.stats()["failed_over"] == fed.tasks_failed_over
        # everything completed on the survivor after the death
        assert fed.stats()["per_shard_completed"][0] == 6


def test_socket_transport_end_to_end():
    """The length-prefixed socket transport is a drop-in for the pipe
    transport: same dataflow, same envelopes."""
    with ProcessFederation(2, SPEC, steal=False,
                           transport="socket") as fed:
        fed.wait_ready()
        a = fed.submit("a", body_value, [21], key="a#0")
        b = fed.submit("b", body_scale, [a], key="b#1")
        rest = [fed.submit("t", body_sleep, [0.005], key=f"t#{i}")
                for i in range(18)]
        fed.run()
        assert b.get() == 42
        assert all(f.resolved for f in rest)
        assert fed.tasks_completed == 20


def test_sim_and_real_federation_agree_on_moldyn_values():
    """The same MolDyn-shaped workload, same keys, same partitioner, steal
    off: the SimClock in-process federation and the 2-process federation
    produce identical values and identical per-shard placement — the
    process boundary changes the transport, not the semantics."""
    clock = SimClock()
    sim = FederatedEngine(2, clock=clock, steal=False,
                          engine_kwargs={"provenance": "summary"})
    for i, eng in enumerate(sim.shards):
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=2, alloc_latency=1e-4,
                          alloc_chunk=2)))
        eng.add_site(f"falkon{i}", FalkonProvider(svc), capacity=2)
    sim_cols = _moldyn_submit(sim)
    sim.run()
    sim_vals = {m: f.get() for m, f in sim_cols.items()}
    sim_placement = sim.stats()["per_shard_completed"]

    with ProcessFederation(2, SPEC, steal=False) as fed:
        fed.wait_ready()
        real_cols = _moldyn_submit(fed)
        fed.run()
        real_vals = {m: f.get() for m, f in real_cols.items()}
        real_placement = fed.stats()["per_shard_completed"]

    assert real_vals == sim_vals
    assert real_placement == sim_placement
    # both routed by the same hash — sanity-check it is the default
    assert sim.partitioner is hash_partitioner
