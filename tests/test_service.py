"""Durable workflow service tests (DESIGN.md §15): multi-tenant
submission, per-app fair share, and resume-from-store.

The fair-share properties target the stride-scheduled `ReadyQueue` drain:
with `fair_share` on, backlogged apps split placements by their `share=`
weights (tolerance-band asserted on the completion-order prefix), and the
starved-app regression documents the exact failure the default
first-arrival drain exhibits.
"""
import pytest

from repro.core import (Engine, FederatedEngine, JobStore, SimClock,
                        WorkflowService)

# ---------------------------------------------------------------------------
# fair share
# ---------------------------------------------------------------------------


def _run_apps(fair_share, loads, shares=None, concurrency=4):
    """Run `loads[app]` equal-cost sim tasks per app through a service;
    return the completion order as a list of app names."""
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=concurrency)
    order: list = []
    with JobStore(":memory:") as store:
        with WorkflowService(eng, store, fair_share=fair_share) as svc:
            for app, n in loads.items():
                share = (shares or {}).get(app, 1.0)
                h = svc.open(app, app=app, share=share)
                proc = h.wf.sim_proc("t", duration=1.0)
                for i in range(n):
                    proc(i).on_done(lambda f, a=app: order.append(a))
            svc.run()
    assert len(order) == sum(loads.values())
    return order


def test_equal_shares_split_throughput_evenly():
    """Two equally-weighted backlogged apps: each gets half the
    placements over the first-half completion prefix (±15%)."""
    order = _run_apps(True, {"a": 100, "b": 100})
    half = order[: len(order) // 2]
    frac_a = half.count("a") / len(half)
    assert 0.35 <= frac_a <= 0.65


def test_weighted_shares_follow_ratio():
    """share=3 vs share=1 → a 3:1 placement ratio while both apps are
    backlogged (±15% band on the prefix where b is still backlogged)."""
    order = _run_apps(True, {"a": 150, "b": 150},
                      shares={"a": 3.0, "b": 1.0})
    # b stays backlogged at least until 4/3 * 150 = 200 completions
    prefix = order[:200]
    frac_a = prefix.count("a") / len(prefix)
    assert 0.60 <= frac_a <= 0.90


def test_three_apps_each_within_band():
    order = _run_apps(True, {"a": 90, "b": 90, "c": 90}, concurrency=6)
    prefix = order[:150]
    for app in ("a", "b", "c"):
        assert 0.20 <= prefix.count(app) / len(prefix) <= 0.47


def test_starved_app_regression():
    """App `big` queues 400 tasks before `late` queues 50.  The default
    first-arrival drain hands every freed slot to `big` until its backlog
    empties — `late` finishes dead last.  Fair share interleaves, so
    `late` is done within the first ~quarter of completions."""
    starved = _run_apps(False, {"big": 400, "late": 50})
    fair = _run_apps(True, {"big": 400, "late": 50})
    last_starved = max(i for i, a in enumerate(starved) if a == "late")
    last_fair = max(i for i, a in enumerate(fair) if a == "late")
    assert last_starved >= 400       # documents the starvation
    assert last_fair <= 150          # fair share fixes it
    # same total work either way
    assert sorted(starved) == sorted(fair)


def test_single_app_unaffected_by_fair_share():
    """With one bucket the fair drain is bypassed entirely — ordering is
    identical to the default drain."""
    a = _run_apps(True, {"only": 60})
    b = _run_apps(False, {"only": 60})
    assert a == b == ["only"] * 60


# ---------------------------------------------------------------------------
# service lifecycle + resume
# ---------------------------------------------------------------------------


def _square_program(handle, n=20):
    sq = handle.wf.atomic(fn=lambda x: x * x, name="square")
    return handle.seal(handle.wf.gather([sq(i) for i in range(n)]))


def test_open_seal_run_result(tmp_path):
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=4)
    with JobStore(str(tmp_path / "s.db")) as store:
        with WorkflowService(eng, store) as svc:
            h = svc.open("etl")
            _square_program(h)
            svc.run()
            assert h.result() == [i * i for i in range(20)]
            assert h.restored == 0
            st = svc.status("etl")
            # gather resolves driver-side — only the n squares journal
            assert st["done"] == 20 and st["failed"] == 0
            assert h.counts()["done"] == 20
        # seal() flipped the durable workflow status on completion
        assert store.load("etl").counts["done"] == 20


def test_resume_restores_done_tasks(tmp_path):
    db = str(tmp_path / "s.db")

    def run_once():
        clock = SimClock()
        eng = Engine(clock)
        eng.local_site(concurrency=4)
        with JobStore(db) as store, WorkflowService(eng, store) as svc:
            h = svc.open("etl")
            out = _square_program(h)
            svc.run()
            return out.get(), h.restored, h.run_id

    first, restored1, run1 = run_once()
    second, restored2, run2 = run_once()
    assert first == second                       # byte-identical results
    assert restored1 == 0 and run1 == 1
    assert restored2 == 20 and run2 == 2         # nothing re-ran


def test_resume_false_reruns_everything(tmp_path):
    db = str(tmp_path / "s.db")
    for expect_restored, resume in ((0, True), (0, False)):
        clock = SimClock()
        eng = Engine(clock)
        eng.local_site(concurrency=4)
        with JobStore(db) as store, WorkflowService(eng, store) as svc:
            h = svc.open("etl", resume=resume)
            _square_program(h)
            svc.run()
            assert h.restored == expect_restored


def test_duplicate_calls_get_distinct_durable_rows(tmp_path):
    """Two calls with identical (name, args) are distinct tasks: the
    occurrence suffix keeps their rows apart, and a deterministic
    re-build restores *both*."""
    db = str(tmp_path / "s.db")

    def run_once():
        clock = SimClock()
        eng = Engine(clock)
        eng.local_site(concurrency=2)
        with JobStore(db) as store, WorkflowService(eng, store) as svc:
            h = svc.open("dup")
            noisy = h.wf.atomic(fn=lambda x: x + 1, name="noisy")
            out = h.seal(h.wf.gather([noisy(7), noisy(7), noisy(7)]))
            svc.run()
            return out.get(), h.restored

    vals1, restored1 = run_once()
    vals2, restored2 = run_once()
    assert vals1 == vals2 == [8, 8, 8]
    assert restored1 == 0 and restored2 == 3     # all three occurrences


def test_failed_workflow_marks_status_failed(tmp_path):
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=2)
    with JobStore(str(tmp_path / "s.db")) as store:
        with WorkflowService(eng, store) as svc:
            h = svc.open("bad")
            boom = h.wf.atomic(fn=int, name="boom")
            h.seal(boom("not-an-int"))
            svc.run()
            assert h._out.failed
            st = svc.status("bad")
            assert st["failed"] == 1
        assert store.load("bad").failed


def test_service_refuses_occupied_seams(tmp_path):
    from repro.core import RestartLog
    clock = SimClock()
    eng = Engine(clock, restart_log=RestartLog(str(tmp_path / "r.rlog")))
    with JobStore(":memory:") as store:
        with pytest.raises(ValueError):
            WorkflowService(eng, store)


def test_open_rejects_bad_and_duplicate_ids():
    eng = Engine(SimClock())
    eng.local_site()
    with JobStore(":memory:") as store:
        with WorkflowService(eng, store) as svc:
            svc.open("w")
            with pytest.raises(ValueError):
                svc.open("w")
            with pytest.raises(ValueError):
                svc.open("x", wf_id="a::b")


def test_two_tenants_share_one_engine(tmp_path):
    """Two workflows opened on the same service run interleaved and each
    lands under its own wf_id in the store."""
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=4)
    with JobStore(str(tmp_path / "s.db")) as store:
        with WorkflowService(eng, store) as svc:
            ha = svc.open("alice")
            hb = svc.open("bob")
            _square_program(ha, n=30)
            _square_program(hb, n=10)
            svc.run()
            assert ha.result() == [i * i for i in range(30)]
            assert hb.result() == [i * i for i in range(10)]
        assert store.load("alice").counts["done"] == 30
        assert store.load("bob").counts["done"] == 10


def test_federated_engine_service_smoke(tmp_path):
    """The service over a 2-shard `FederatedEngine`: one journal and one
    resume view shared by every shard; resume works across the shard
    boundary."""
    db = str(tmp_path / "fed.db")

    def run_once():
        clock = SimClock()
        fed = FederatedEngine(2, clock=clock, steal=False)
        for eng in fed.shards:
            eng.local_site(concurrency=2)
        with JobStore(db) as store, WorkflowService(fed, store) as svc:
            h = svc.open("fedwf")
            _square_program(h, n=16)
            svc.run()
            return h.result(), h.restored

    vals1, restored1 = run_once()
    vals2, restored2 = run_once()
    assert vals1 == vals2 == [i * i for i in range(16)]
    assert restored1 == 0 and restored2 == 16
