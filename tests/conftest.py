"""Shared test configuration.

If `hypothesis` is not installed (it is an optional dev dependency — see
requirements-dev.txt), register the deterministic fallback shim so the four
property-test modules still import and run a reduced deterministic sweep
instead of erroring at collection.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_shim.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
