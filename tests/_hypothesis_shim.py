"""Tiny deterministic stand-in for `hypothesis` when it is not installed.

Covers exactly the subset the test suite uses — `given`, `settings`, and the
strategies `integers`, `sampled_from`, `lists`, `floats`, `booleans`,
`just` — by running each property test over a fixed number of samples drawn
from a seeded RNG.  It is NOT a property-testing engine (no shrinking, no
database, no assumptions); it exists so the suite degrades gracefully
instead of dying at import.  Installed into `sys.modules["hypothesis"]` by
tests/conftest.py only when the real package is missing; install the real
one via requirements-dev.txt to get full coverage.
"""
from __future__ import annotations

import random
from types import SimpleNamespace

_DEFAULT_EXAMPLES = 8
# cap: the shim is a fallback smoke layer, not an exhaustive fuzzer; keep
# suite runtime sane when the real hypothesis is absent
_MAX_EXAMPLES_CAP = 8


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value):
    return _Strategy(lambda rng: value)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10, **_kw):
    return _Strategy(lambda rng: [elements._sample(rng)
                                  for _ in range(rng.randint(min_size,
                                                             max_size))])


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def runner(*args, **kwargs):
            # @settings may sit above @given (attribute lands on `runner`)
            # or below it (attribute lands on the wrapped `fn`)
            n = min(getattr(runner, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES)),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(0)
            for _ in range(n):
                pos = tuple(s._sample(rng) for s in arg_strategies)
                kws = {k: s._sample(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kws, **kwargs)

        # deliberately no functools.wraps: pytest must not see the wrapped
        # function's signature, or it would demand fixtures for every
        # strategy-supplied argument
        runner.__name__ = getattr(fn, "__name__", "given_runner")
        runner.__doc__ = getattr(fn, "__doc__", None)
        runner.hypothesis = SimpleNamespace(inner_test=fn)
        return runner

    return deco


strategies = SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans, just=just,
    sampled_from=sampled_from, lists=lists,
)

__all__ = ["given", "settings", "strategies"]
