"""Provenance (VDC / Kickstart analog, paper §3.14) and Falkon metrics."""
import json

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, SimClock)
from repro.core.provenance import VDC


def test_invocation_records_have_kickstart_fields():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=2)
    out = eng.submit("compute", lambda: 42)
    eng.run()
    rec = eng.vdc.records[0]
    assert rec.name == "compute"
    assert rec.exit_status == "ok"
    assert rec.site == "localhost"
    assert rec.end_time >= rec.start_time >= rec.submit_time >= 0
    assert rec.queue_time >= 0 and rec.run_time >= 0


def test_vdc_derivation_chain():
    vdc = VDC()
    vdc.register_dataset("raw", producer="stage0", meta={})
    vdc.register_dataset("projected", producer="mProjectPP",
                         meta={"derived_from": "raw"})
    vdc.register_dataset("mosaic", producer="mAdd",
                         meta={"derived_from": "projected"})
    chain = vdc.derivation("mosaic")["chain"]
    assert [c["dataset"] for c in chain] == ["mosaic", "projected", "raw"]
    assert chain[0]["producer"] == "mAdd"


def test_vdc_jsonl_persistence(tmp_path):
    path = str(tmp_path / "vdc.jsonl")
    clock = SimClock()
    eng = Engine(clock, vdc=VDC(path))
    eng.local_site()
    eng.submit("a", lambda: 1)
    eng.run()
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    assert len(recs) == 1 and recs[0]["name"] == "a"


def test_falkon_executor_task_logs_support_fig18_view():
    """Per-executor (start, end) task logs — the data behind the paper's
    Fig 18 executor view."""
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=4, alloc_latency=0.0)), trace=True)
    eng = Engine(clock)
    eng.add_site("f", FalkonProvider(svc), capacity=4)
    outs = [eng.submit(f"t{i}", None, duration=2.0) for i in range(12)]
    eng.run()
    assert all(o.resolved for o in outs)
    total_tasks = sum(len(e.task_log) for e in svc.executors)
    assert total_tasks == 12
    for e in svc.executors:
        # task intervals on one executor never overlap
        spans = sorted(e.task_log)
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert e1 <= s2 + 1e-9
    u = svc.utilization()
    assert 0.9 < u["efficiency"] <= 1.0  # fully packed, 0 alloc latency
