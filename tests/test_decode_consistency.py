"""Numerical consistency: prefill + incremental decode == full forward.

For each architecture family: run prefill over a prompt, then decode one
token; separately run prefill over (prompt + token); the next-token logits
must agree.  This exercises every cache type (GQA global, local ring, MLA
absorbed-latent, mamba state, rg-lru state, whisper cross)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T
from repro.models.params import init_tree

FAMS = ["qwen1.5-0.5b",        # dense GQA, global attention
        "gemma3-27b",          # local windows + qk-norm
        "deepseek-v2-236b",    # MLA absorbed decode + MoE
        "falcon-mamba-7b",     # SSM state
        "recurrentgemma-9b",   # RG-LRU + local MQA
        "whisper-large-v3"]    # enc-dec cross attention


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_prefill(name):
    cfg = registry.smoke_config(name)
    descs = T.build_descriptors(cfg)
    params = init_tree(descs, jax.random.PRNGKey(0))
    B, S = 2, 32
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model),
                            jnp.float32) if cfg.enc_dec else None

    # prefill S-1, decode token S-1 -> logits for position S-1
    logits_p, caches = T.prefill(cfg, params, toks[:, :S - 1], enc_feats=enc)
    # decode caches from the (S-1)-prefill are sized S-1; rebuild cache at
    # size S by prefilling into a padded buffer: decode writes at pos S-1.
    # Our prefill cache length == prompt length, so pad token caches.
    caches = _pad_caches(cfg, caches, S)
    logits_d, _ = T.decode_step(cfg, params, caches, toks[:, S - 1:S],
                                jnp.asarray(S - 1, jnp.int32))

    # ground truth: prefill over the full S tokens gives last-position logits
    logits_full, _ = T.prefill(cfg, params, toks, enc_feats=enc)

    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(logits_full[:, 0], np.float32), rtol=5e-2, atol=5e-2)


def _pad_caches(cfg, caches, new_len):
    """Grow attention caches from prefill length to new_len (ring caches and
    recurrent states are already fixed-size)."""

    def grow(leaf):
        return leaf

    out = []
    for g in caches:
        def fix(d):
            if not isinstance(d, dict):
                return d
            fixed = {}
            for k, v in d.items():
                if isinstance(v, dict):
                    fixed[k] = fix(v)
                else:
                    fixed[k] = v
            # global attention caches: (reps, B, T, H, D) -> pad T;
            # cross-attention caches (T == enc_frames != new_len-1) are kept
            if set(fixed) == {"k", "v"} and fixed["k"].ndim == 5:
                T_cur = fixed["k"].shape[2]
                if T_cur == new_len - 1:
                    pad = new_len - T_cur
                    fixed["k"] = jnp.pad(fixed["k"],
                                         ((0, 0), (0, 0), (0, pad), (0, 0),
                                          (0, 0)))
                    fixed["v"] = jnp.pad(fixed["v"],
                                         ((0, 0), (0, 0), (0, pad), (0, 0),
                                          (0, 0)))
            # local ring caches: grow the ring so position 0 is not evicted
            # (the smoke windows exceed the prompt, so full-forward keeps it)
            if set(fixed) == {"k", "v", "pos"}:
                T_cur = fixed["k"].shape[2]
                if T_cur == new_len - 1:
                    pad = new_len - T_cur
                    fixed["k"] = jnp.pad(fixed["k"],
                                         ((0, 0), (0, 0), (0, pad), (0, 0),
                                          (0, 0)))
                    fixed["v"] = jnp.pad(fixed["v"],
                                         ((0, 0), (0, 0), (0, pad), (0, 0),
                                          (0, 0)))
                    fixed["pos"] = jnp.pad(fixed["pos"],
                                           ((0, 0), (0, 0), (0, pad)),
                                           constant_values=-1)
            if set(fixed) == {"c_kv", "k_rope"}:
                T_cur = fixed["c_kv"].shape[2]
                if T_cur < new_len:
                    pad = new_len - T_cur
                    fixed["c_kv"] = jnp.pad(
                        fixed["c_kv"], ((0, 0), (0, 0), (0, pad), (0, 0)))
                    fixed["k_rope"] = jnp.pad(
                        fixed["k_rope"], ((0, 0), (0, 0), (0, pad), (0, 0)))
            return fixed

        out.append(fix(g))
    return out
