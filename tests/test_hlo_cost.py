"""HLO cost analyzer: validated against hand-computable compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloModule, analyze


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    t = _compile(lambda a, b: a @ b, (512, 512), (512, 512))
    c = analyze(t)
    assert c.flops == pytest.approx(2 * 512 ** 3, rel=0.01)


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return y

    c = analyze(_compile(f, (512, 512), (512, 512)))
    assert c.flops == pytest.approx(16 * 2 * 512 ** 3, rel=0.02)


def test_nested_scan_multiplies():
    def f(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = analyze(_compile(f, (512, 512), (512, 512)))
    assert c.flops == pytest.approx(16 * 2 * 512 ** 3, rel=0.02)


def test_bytes_scale_with_tensor_size():
    c1 = analyze(_compile(lambda a, b: a + b, (1024, 1024), (1024, 1024)))
    c2 = analyze(_compile(lambda a, b: a + b, (2048, 1024), (2048, 1024)))
    assert c2.bytes == pytest.approx(2 * c1.bytes, rel=0.05)
    # add: read 2 operands + write 1 result
    assert c1.bytes == pytest.approx(3 * 1024 * 1024 * 4, rel=0.05)


def test_collective_wire_bytes():
    import os
    import subprocess
    import sys
    if not hasattr(jax.sharding, "Mesh"):
        pytest.skip("this JAX version has no jax.sharding.Mesh; "
                    "cannot build a multi-device mesh")
    # needs >1 device: run in a subprocess with forced host device count;
    # mesh construction goes through compat_make_mesh because
    # jax.sharding.AxisType does not exist on every supported JAX version
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("d",))
x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P("d")))
f = jax.jit(lambda a: a.sum(), out_shardings=NamedSharding(mesh, P()))
c = analyze(f.lower(x).compile().as_text())
# scalar f32 all-reduce: 2 * (7/8) * 4 = 7 bytes on the wire
assert abs(c.coll_wire - 7.0) < 0.01, c.coll_wire
assert "all-reduce" in c.coll_by_kind
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, cwd="/root/repo")
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_conditional_branches_counted():
    """lax.cond branches are referenced via branch_computations=, not
    calls=; the walker must still descend into them (summed: upper bound)."""
    def f(p, a):
        return jax.lax.cond(p, lambda x: x * 2.0, lambda x: x + 1.0, a)

    args = [jax.ShapeDtypeStruct((), jnp.bool_),
            jax.ShapeDtypeStruct((256, 256), jnp.float32)]
    c = analyze(jax.jit(f).lower(*args).compile().as_text())
    assert c.flops == pytest.approx(2 * 256 * 256, rel=0.05)
    # each branch reads + writes a 256 KB buffer
    assert c.bytes == pytest.approx(2 * 2 * 256 * 256 * 4, rel=0.05)


def test_wrapped_long_lines_parse():
    """Tuple-typed whiles wrap across physical lines in HLO dumps; the
    parser must reassemble them (regression for the while.706 bug)."""
    def f(x, w):
        def body(carry, _):
            a, b, c, d, e = carry
            a = jnp.tanh(a @ w)
            return (a, b + 1.0, c * 2.0, d - 1.0, e + a.sum()), None

        init = (x, x, x, x, jnp.zeros(()))
        (a, *_), _ = jax.lax.scan(body, init, None, length=8)
        return a

    t = _compile(f, (256, 256), (256, 256))
    c = analyze(t)
    assert c.flops == pytest.approx(8 * 2 * 256 ** 3, rel=0.1)


# ---------------------------------------------------------------------------
# duration prediction over real kernel task bodies (DESIGN.md §11)
# ---------------------------------------------------------------------------


def test_matmul_task_cost_matches_hand_computation():
    from repro.kernels.ops import matmul_task
    from repro.launch.hlo_cost import DurationPredictor

    d = 64
    pred = DurationPredictor()
    x = np.ones((d,), np.float32)
    w = np.ones((d, d), np.float32)
    c = pred.predict_cost(matmul_task, [x, w])
    # dominated by the (d,) @ (d, d) contraction: 2*d^2 flops; tanh/sum/add
    # contribute O(d) on top
    assert c.flops == pytest.approx(2 * d * d, rel=0.05)
    # reads x (4d) + w (4d^2), writes the (d,) output: ~4d^2 + O(d)
    assert c.bytes == pytest.approx(4 * d * d, rel=0.2)


def test_attention_task_cost_matches_hand_computation():
    from repro.kernels.ops import attention_task
    from repro.launch.hlo_cost import DurationPredictor

    H, S, D = 2, 32, 16
    pred = DurationPredictor()
    q = np.ones((H, S, D), np.float32)
    c = pred.predict_cost(attention_task, [q, q, q])
    # the two einsums cost 4*H*S^2*D; mask/softmax/scale add a bounded
    # overhead on top, so the analyzed total sits in [1x, 1.5x] of that
    core = 4 * H * S * S * D
    assert core <= c.flops <= 1.5 * core


def test_prediction_cache_hits_by_signature():
    from repro.kernels.ops import matmul_task
    from repro.launch.hlo_cost import DeviceModel, DurationPredictor

    pred = DurationPredictor(device=DeviceModel())
    args_a = [np.ones((16,), np.float32), np.ones((16, 16), np.float32)]
    args_b = [np.zeros((16,), np.float32), np.ones((16, 16), np.float32)]
    d1 = pred.predict_duration(matmul_task, args_a)
    # same (callable, shapes) signature, different values: cache hit
    d2 = pred.predict_duration(matmul_task, args_b)
    assert d1 == d2
    assert d1 >= pred.device.launch_overhead
    assert pred.compiles == 1 and pred.hits == 1
    # a different shape is a different signature: one more compile
    pred.predict_duration(matmul_task,
                          [np.ones((32,), np.float32),
                           np.ones((32, 32), np.float32)])
    assert pred.compiles == 2
