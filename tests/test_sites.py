"""Unit tests for sites + load balancing (DESIGN.md §3, paper §3.13):

  * `sites_for` cache invalidation when sites are added mid-run;
  * suspended-site skip in `pick`;
  * proportional-weight equilibrium (the Fig 11 score/capacity split);
  * deterministic tie-breaking (earliest-registered site, stable under
    SimClock — not dict/insertion luck);
  * the data-affinity term: sites holding a task's inputs are boosted,
    priced against the StagingCostModel, and the no-inputs path is
    behaviorally identical to the score-only balancer;
  * the `idle_slots` steal interface.
"""
import pytest

from repro.core import (DataLayer, DataObject, Engine, LocalProvider,
                        SharedStore, SimClock, StagingCostModel, Workflow)
from repro.core.sites import LoadBalancer, Site, _affinity_boost


def _site(name, capacity=1, score=1.0, apps=None):
    return Site(name, provider=None, capacity=capacity, apps=apps,
                score=score)


# ---------------------------------------------------------------------------
# per-app index invalidation
# ---------------------------------------------------------------------------

def test_sites_for_cache_invalidates_on_add_site():
    lb = LoadBalancer([_site("a", apps={"x"})])
    assert [s.name for s in lb.sites_for("x")] == ["a"]
    assert [s.name for s in lb.sites_for("y")] == []
    # a site added after the cache was populated must appear immediately,
    # including in the previously-empty candidate list
    lb.add_site(_site("b", apps={"x", "y"}))
    assert [s.name for s in lb.sites_for("x")] == ["a", "b"]
    assert [s.name for s in lb.sites_for("y")] == ["b"]
    # a catch-all site (apps=None) joins every candidate list
    lb.add_site(_site("c"))
    assert [s.name for s in lb.sites_for("x")] == ["a", "b", "c"]
    assert [s.name for s in lb.sites_for("zzz")] == ["c"]


def test_add_site_mid_run_is_picked_up_by_engine():
    """The engine-level view of the staleness hazard: a site added while
    tasks are in flight serves subsequent dispatches."""
    clock = SimClock()
    eng = Engine(clock)
    eng.add_site("first", LocalProvider(clock, 1), capacity=1)
    first = eng.submit("t0", None, duration=10.0)

    def add_late():
        eng.add_site("late", LocalProvider(clock, 4), capacity=4)

    clock.schedule(1.0, add_late)
    late = []

    def submit_late():
        late.extend(eng.submit(f"l{i}", None, duration=1.0)
                    for i in range(4))

    clock.schedule(2.0, submit_late)
    eng.run()
    assert first.resolved and all(o.resolved for o in late)
    # the late tasks ran on the new site (done at t=3), not behind the
    # 10 s task on the original site
    assert clock.now() == pytest.approx(10.0)
    assert eng.balancer.sites[1].stats.completed == 4


# ---------------------------------------------------------------------------
# pick: suspension, equilibrium, determinism
# ---------------------------------------------------------------------------

def test_pick_skips_suspended_sites():
    a, b = _site("a"), _site("b")
    lb = LoadBalancer([a, b])
    a.suspended_until = 100.0
    assert lb.pick(None, now=50.0) is b
    assert lb.pick(None, now=100.0) is a      # suspension lapsed, tie -> a
    b.suspended_until = 100.5
    a.suspended_until = 100.5
    assert lb.pick(None, now=100.0) is None   # everyone suspended


def test_pick_weight_is_proportional_to_score_and_capacity():
    """Fig 11 shape: under saturation, backlog settles proportional to
    score x capacity — the higher-weight site keeps winning until its
    queue depth eats its advantage."""
    fast = _site("fast", capacity=4, score=2.0)
    slow = _site("slow", capacity=2, score=1.0)
    lb = LoadBalancer([fast, slow])
    picks = {"fast": 0, "slow": 0}
    for _ in range(30):
        s = lb.pick(None, now=0.0)
        s.outstanding += 1
        picks[s.name] += 1
    # weight ratio 8:2 -> fast absorbs ~4x the backlog at equilibrium
    assert picks["fast"] / picks["slow"] == pytest.approx(4.0, rel=0.25)
    # queue-depth equilibrium: final backlogs sit near the weight ratio
    assert fast.outstanding / slow.outstanding == pytest.approx(4.0,
                                                                rel=0.25)


def test_fig11_two_site_split_under_engine():
    """End-to-end Fig 11 shape: two equal-score sites with 2:1 capacity
    split a wide workload roughly 2:1."""
    clock = SimClock()
    eng = Engine(clock)
    eng.add_site("big", LocalProvider(clock, 8), capacity=8)
    eng.add_site("small", LocalProvider(clock, 4), capacity=4)
    wf = Workflow("t", eng)
    out = wf.gather([eng.submit(f"t{i}", None, duration=1.0)
                     for i in range(480)])
    eng.run()
    assert out.resolved
    big, small = eng.balancer.sites
    assert big.stats.completed + small.stats.completed == 480
    ratio = big.stats.completed / small.stats.completed
    assert ratio == pytest.approx(2.0, rel=0.3)


def test_pick_tie_breaks_to_earliest_registered_site():
    """Equal-weight candidates must resolve by registration order — the
    documented deterministic tie-break — every time."""
    sites = [_site(f"s{i}") for i in range(5)]
    lb = LoadBalancer(sites)
    assert all(lb.pick(None, now=0.0) is sites[0] for _ in range(10))
    # loading s0 shifts the tie to the next-registered site, not to an
    # arbitrary dict ordering
    sites[0].outstanding = 1
    assert lb.pick(None, now=0.0) is sites[1]


# ---------------------------------------------------------------------------
# data-affinity term
# ---------------------------------------------------------------------------

def _layer_with_holders(names):
    dl = DataLayer(SharedStore(), StagingCostModel(), cache_capacity=1e9)
    dl._holders = {n: {0: None} for n in names}
    return dl


def test_pick_prefers_site_holding_inputs():
    a, b = _site("a"), _site("b")
    lb = LoadBalancer([a, b])
    obj = DataObject("x.dat", 200e6)
    lb.set_affinity("b", _layer_with_holders(["x.dat"]))
    # without inputs the tie resolves to a (registration order) ...
    assert lb.pick(None, now=0.0) is a
    # ... with inputs the holder site wins despite registration order
    assert lb.pick(None, now=0.0, inputs=(obj,)) is b


def test_affinity_boost_is_priced_against_staging_cost():
    cost = StagingCostModel()
    dl = _layer_with_holders(["x.dat"])
    big, small = DataObject("x.dat", 500e6), DataObject("x.dat", 1e3)
    # full coverage: the boost IS the shared-vs-local read-time ratio the
    # cost model prices — bandwidth-bound for the 500 MB archive (~4x),
    # latency-bound for the 1 KB file (~10x)
    for obj in (big, small):
        expected = cost.shared_read_time(obj.size) / \
            cost.local_read_time(obj.size)
        assert _affinity_boost(dl, (obj,)) == pytest.approx(expected)
        assert expected > 1.0
    expected = cost.shared_read_time(big.size) / cost.local_read_time(big.size)
    # partial coverage scales the advantage by covered bytes
    other = DataObject("y.dat", 500e6)
    assert _affinity_boost(dl, (big, other)) == \
        pytest.approx(1.0 + 0.5 * (expected - 1.0), rel=0.01)
    # no coverage -> exactly no boost
    assert _affinity_boost(_layer_with_holders([]), (big,)) == 1.0


def test_no_inputs_path_is_unchanged_by_affinity_registration():
    """Registering a data layer must not perturb placement of tasks with
    no declared inputs — pick-for-pick identical to an unregistered
    balancer, including tie-breaks."""
    def run_picks(register):
        sites = [_site(f"s{i}", capacity=2, score=1.0 + 0.1 * i)
                 for i in range(4)]
        lb = LoadBalancer(sites)
        if register:
            lb.set_affinity("s2", _layer_with_holders(["x.dat"]))
        order = []
        for _ in range(40):
            s = lb.pick(None, now=0.0)
            s.outstanding += 1
            order.append(s.name)
        return order

    assert run_picks(register=True) == run_picks(register=False)


def test_affinity_respects_require_room_and_suspension():
    holder = _site("holder", capacity=1)
    other = _site("other", capacity=1)
    lb = LoadBalancer([holder, other])
    lb.set_affinity("holder", _layer_with_holders(["x.dat"]))
    obj = DataObject("x.dat", 200e6)
    holder.outstanding = 2      # over 1 x slack=2.0 throttle
    assert lb.pick(None, now=0.0, require_room=True, slack=2.0,
                   inputs=(obj,)) is other
    holder.outstanding = 0
    holder.suspended_until = 10.0
    assert lb.pick(None, now=0.0, inputs=(obj,)) is other


# ---------------------------------------------------------------------------
# steal interface
# ---------------------------------------------------------------------------

def test_idle_slots_counts_free_nonsuspended_capacity():
    a = _site("a", capacity=4)
    b = _site("b", capacity=2)
    lb = LoadBalancer([a, b])
    assert lb.idle_slots(now=0.0) == 6
    a.outstanding = 3
    assert lb.idle_slots(now=0.0) == 3
    b.suspended_until = 5.0
    assert lb.idle_slots(now=0.0) == 1
    assert lb.idle_slots(now=5.0) == 3       # suspension lapsed
    a.outstanding = 10                        # over-subscribed: clamps at 0
    assert lb.idle_slots(now=5.0) == 2
