"""Per-architecture smoke tests: reduced configs of the same family, one
forward/train step on CPU, asserting output shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import transformer as T
from repro.models.params import init_tree
from repro.optim import adamw
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step


def _setup(name, B=2, S=64):
    cfg = registry.smoke_config(name)
    descs = T.build_descriptors(cfg)
    params = init_tree(descs, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.enc_dec:
        batch["enc_feats"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    return cfg, params, batch


@pytest.mark.parametrize("name", registry.ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg, params, batch = _setup(name)
    loss, metrics = T.forward_train(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    hp = adamw.Hyper(lr=1e-3, warmup=2)
    step = jax.jit(make_train_step(cfg, hp))
    opt = adamw.init(params)
    p2, o2, m = step(params, opt, batch, jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # parameters actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(p2)[0]
    assert l0.shape == l1.shape
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert changed, f"{name}: no parameter changed after a step"


@pytest.mark.parametrize("name", registry.ARCH_NAMES)
def test_prefill_and_decode_shapes(name):
    cfg, params, batch = _setup(name, B=2, S=32)
    pf = make_prefill_step(cfg)
    logits, caches = pf(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    sv = make_serve_step(cfg)
    nxt, caches2 = sv(params, caches, batch["tokens"][:, :1],
                      jnp.asarray(31, jnp.int32))
    assert nxt.shape == (2, 1)
    assert nxt.dtype == jnp.int32
    assert bool(jnp.all(nxt >= 0)) and bool(jnp.all(nxt < cfg.vocab))


def test_all_full_configs_construct():
    """Full (non-reduced) configs build descriptor trees with the assigned
    dimensions; no arrays are allocated."""
    expect_layers = {
        "recurrentgemma-9b": 38, "deepseek-v2-236b": 60,
        "granite-moe-3b-a800m": 32, "qwen1.5-0.5b": 24, "stablelm-12b": 40,
        "qwen2-1.5b": 28, "gemma3-27b": 62, "qwen2-vl-7b": 28,
        "whisper-large-v3": 32, "falcon-mamba-7b": 64,
    }
    for name in registry.ARCH_NAMES:
        cfg = registry.get_config(name)
        assert cfg.n_layers == expect_layers[name], name
        n = cfg.param_count()
        assert n > 1e8, f"{name}: param count {n} suspiciously small"
        if cfg.moe is not None:
            assert cfg.active_param_count() < n


def test_param_counts_match_public_models():
    """Sanity-check total parameter counts against the published sizes."""
    expected = {
        "deepseek-v2-236b": (200e9, 260e9),
        "gemma3-27b": (24e9, 30e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "whisper-large-v3": (1.2e9, 2.0e9),
    }
    for name, (lo, hi) in expected.items():
        n = registry.get_config(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
