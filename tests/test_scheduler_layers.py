"""Unit tests for the layered scheduler subsystem (DESIGN.md §1):

  * WorkerPoolProvider subclasses preserve FIFO ordering and the
    concurrency cap of the seed's duplicated pool logic;
  * the engine's batched pending-drain dispatches every unblocked task
    after a completion and does not head-of-line-block across apps;
  * bounded streaming metrics report the same aggregates as the full
    per-event trace logs on a 10k-task run.
"""
import pytest

from repro.core import (BatchSchedulerProvider, DRPConfig, Engine,
                        FalkonConfig, FalkonProvider, FalkonService,
                        LocalProvider, SimClock, StreamStat,
                        WorkerPoolProvider)
from repro.core.providers import Provider
from repro.core.task import Task
from repro.core.futures import DataFuture


def _mk_task(name, duration=1.0, fn=None):
    return Task(name, fn, [], DataFuture(name), duration, None,
                retries=0, durable=False, key=name)


# ---------------------------------------------------------------------------
# WorkerPoolProvider semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda clock: LocalProvider(clock, concurrency=3),
    lambda clock: BatchSchedulerProvider(clock, nodes=3, submit_rate=1e9,
                                         sched_latency=0.0),
])
def test_worker_pool_preserves_fifo_order(make):
    clock = SimClock()
    prov = make(clock)
    started, finished = [], []
    for i in range(10):
        t = _mk_task(f"t{i}", duration=1.0)
        prov.submit(t, lambda ok, v, e, i=i: finished.append(i))
    clock.run()
    assert finished == list(range(10))


@pytest.mark.parametrize("make,slots", [
    (lambda clock: LocalProvider(clock, concurrency=4), 4),
    (lambda clock: BatchSchedulerProvider(clock, nodes=4, submit_rate=1e9,
                                          sched_latency=0.0), 4),
])
def test_worker_pool_respects_concurrency_cap(make, slots):
    clock = SimClock()
    prov = make(clock)
    running = [0]
    peak = [0]
    done = []

    def body(running=running, peak=peak):
        running[0] += 1
        peak[0] = max(peak[0], running[0])
        return None

    for i in range(16):
        t = _mk_task(f"t{i}", duration=1.0, fn=body)

        def fin(ok, v, e):
            running[0] -= 1
            done.append(ok)

        prov.submit(t, fin)
    clock.run()
    assert len(done) == 16 and all(done)
    # tasks execute at completion events; with 4 slots and equal durations,
    # exactly 4 tasks complete per virtual second
    assert clock.now() == pytest.approx(4.0)
    assert prov._running == 0


def test_worker_pool_base_is_shared():
    """Both pool providers actually ride the shared base class."""
    assert issubclass(LocalProvider, WorkerPoolProvider)
    assert issubclass(BatchSchedulerProvider, WorkerPoolProvider)


# ---------------------------------------------------------------------------
# batched pending-drain
# ---------------------------------------------------------------------------

def test_drain_dispatches_all_unblocked_after_burst_completion():
    """A burst of simultaneous completions frees many slots; ONE drain pass
    must dispatch every task that now has room (the seed popped one pending
    task per completion event)."""
    clock = SimClock()
    eng = Engine(clock)
    eng.site_slack = 1.0  # throttle at exactly `capacity` outstanding
    # two equal sites so the multi-site throttle path (require_room) engages
    eng.add_site("a", LocalProvider(clock, concurrency=4), capacity=4)
    eng.add_site("b", LocalProvider(clock, concurrency=4), capacity=4)
    outs = [eng.submit(f"t{i}", None, duration=1.0, app="main")
            for i in range(32)]
    assert len(eng._pending) == 32 - 8  # throttle held the rest
    eng.run()
    assert all(o.resolved for o in outs)
    # 32 tasks, 8-wide site, 1s each: any single-task-per-completion
    # stutter would stretch the makespan past 4 virtual seconds
    assert clock.now() == pytest.approx(4.0)
    assert not eng._pending


def test_drain_skips_blocked_app_without_head_of_line_blocking():
    """A completion on app-a's site must dispatch the next app-a task even
    when older app-b tasks (whose site is still full) sit ahead of it in
    the ready queue."""
    clock = SimClock()
    eng = Engine(clock)
    eng.site_slack = 1.0
    eng.add_site("site_a", LocalProvider(clock, concurrency=1), capacity=1,
                 apps={"a"})
    eng.add_site("site_b", LocalProvider(clock, concurrency=1), capacity=1,
                 apps={"b"})
    # fill both sites, then queue: b, b, a   (b tasks are older)
    first_a = eng.submit("a0", None, duration=1.0, app="a")
    first_b = eng.submit("b0", None, duration=100.0, app="b")
    slow_bs = [eng.submit(f"b{i}", None, duration=100.0, app="b")
               for i in (1, 2)]
    quick_a = eng.submit("a1", None, duration=1.0, app="a")
    eng.run()
    assert first_a.resolved and quick_a.resolved
    assert first_b.resolved and all(o.resolved for o in slow_bs)
    # a1 ran right after a0 (t=2), not after the 100s b-backlog drained
    rec = [r for r in eng.vdc.records if r.name == "a1"]
    assert rec and rec[0].end_time == pytest.approx(2.0)


def test_per_app_site_index_matches_linear_scan():
    clock = SimClock()
    eng = Engine(clock)
    a = eng.add_site("a", LocalProvider(clock), capacity=1, apps={"x"})
    b = eng.add_site("b", LocalProvider(clock), capacity=1, apps={"y"})
    c = eng.add_site("c", LocalProvider(clock), capacity=1)  # everything
    lb = eng.balancer
    assert lb.sites_for("x") == [a, c]
    assert lb.sites_for("y") == [b, c]
    assert lb.sites_for(None) == [a, b, c]
    assert lb.sites_for("z") == [c]
    # index invalidates on add_site
    d = eng.add_site("d", LocalProvider(clock), capacity=1, apps={"z"})
    assert lb.sites_for("z") == [c, d]


# ---------------------------------------------------------------------------
# bounded metrics vs full traces
# ---------------------------------------------------------------------------

def _run_falkon(n_tasks, trace):
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=64, alloc_latency=5.0, alloc_chunk=16)),
        trace=trace)
    eng = Engine(clock, provenance="records" if trace else "summary")
    eng.add_site("f", FalkonProvider(svc), capacity=64)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(n_tasks)]
    eng.run()
    assert all(o.resolved for o in outs)
    return eng, svc


def test_bounded_metrics_match_unbounded_aggregates_10k():
    n = 10_000
    eng_t, svc_t = _run_falkon(n, trace=True)
    eng_b, svc_b = _run_falkon(n, trace=False)

    # trace mode populated the raw logs; bounded mode kept them empty
    assert len(svc_t.queue_len_log) > 0 and len(svc_t.alloc_log) > 0
    assert sum(e.task_log.count for e in svc_t.executors) == n
    assert svc_b.queue_len_log == [] and svc_b.alloc_log == []
    assert all(e.task_log == [] for e in svc_b.executors)

    # the raw logs are *bounded* now (DESIGN.md §12): exact .count with
    # capped kept entries, instead of the seed's O(tasks) plain lists
    assert svc_t.queue_len_log.count == svc_t.queue_stat.count
    assert len(svc_t.queue_len_log) <= svc_t.queue_len_log.cap
    assert svc_t.alloc_log.count == svc_t.alloc_stat.count
    assert all(len(e.task_log) <= e.task_log.cap for e in svc_t.executors)

    # ... and the streaming summaries agree exactly across modes
    assert svc_b.dispatched == svc_t.dispatched == n
    assert svc_b.tasks_finished == n
    assert svc_b.peak_queue == svc_t.peak_queue
    assert svc_b.queue_stat.count == svc_t.queue_stat.count
    assert svc_b.queue_stat.peak == svc_t.queue_stat.peak
    assert svc_b.queue_stat.total == pytest.approx(svc_t.queue_stat.total)
    assert svc_b.alloc_stat.count == svc_t.alloc_stat.count
    assert svc_b.alloc_stat.total == svc_t.alloc_stat.total
    assert sum(e.tasks_done for e in svc_b.executors) == \
        sum(e.task_log.count for e in svc_t.executors)

    # reservoirs stay bounded and identical runs keep identical reservoirs
    # (deterministic decimation — no RNG anywhere in the metrics path)
    assert len(svc_b.queue_stat.sample) < svc_b.queue_stat.cap
    assert svc_b.queue_stat.sample == svc_t.queue_stat.sample
    # decimation never manufactures values: every kept queue-length entry
    # is bounded by the exact peak counter
    assert max(q for _, q in svc_t.queue_len_log) <= svc_t.peak_queue

    # summary-mode provenance: same aggregate counts, no stored records
    assert eng_b.vdc.summary()["invocations"] == \
        eng_t.vdc.summary()["invocations"] == n
    assert eng_b.vdc.summary()["ok"] == n
    assert len(eng_b.vdc.records) == 0 and len(eng_t.vdc.records) == n
    assert eng_b.vdc.summary()["total_run_time"] == \
        pytest.approx(eng_t.vdc.summary()["total_run_time"])


def test_stream_stat_decimation_is_bounded_and_exact():
    s = StreamStat(cap=64)
    n = 100_000
    for i in range(n):
        s.observe(float(i), float(i % 97))
    assert s.count == n
    assert s.total == sum(float(i % 97) for i in range(n))
    assert s.peak == 96.0
    assert s.last == float((n - 1) % 97)
    assert len(s.sample) < 64


def test_vdc_max_records_bounds_memory_but_not_counts():
    from repro.core import VDC
    clock = SimClock()
    eng = Engine(clock, vdc=VDC(max_records=100))
    eng.local_site(concurrency=8)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(500)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert len(eng.vdc.records) == 100       # bounded
    assert eng.vdc.summary()["invocations"] == 500  # exact
