"""Unit tests for the federation subsystem (DESIGN.md §8):

  * partitioning — deterministic hash partitioner, pluggable partitioners,
    federation-wide dataflow correctness across shards;
  * cross-shard futures — mailbox proxy delivery (values and failures),
    coalesced flush events, bounded ownership bookkeeping;
  * work stealing — steal-half batches under a skewed partition, bounded
    per-shard idle time, thief eligibility via the LoadBalancer steal
    interface, app-validity guard;
  * sharded data layer — cross-shard directory maintenance, steal-time
    restage pricing, bounded StreamStat steal metrics;
  * determinism — identical replays under SimClock;
  * serialized dispatch — the 487 tasks/s dispatcher ceiling that makes
    N shards beat one engine, default-off timing unchanged.
"""
import pytest

from repro.core import (DataObject, DRPConfig, Engine, FalkonConfig,
                        FalkonProvider, FalkonService, FederatedEngine,
                        LocalProvider, ShardedDataLayer, SimClock, Workflow,
                        hash_partitioner, skewed_partitioner)
from repro.core.federation import WorkStealer


def _falkon_shard(clock, execs=8, alloc=1.0, data_layer=None,
                  serialize=False):
    return FalkonService(clock, FalkonConfig(
        serialize_dispatch=serialize,
        drp=DRPConfig(max_executors=execs, alloc_latency=alloc,
                      alloc_chunk=execs)), data_layer=data_layer)


def _federation(n_shards=4, execs=8, partitioner=None, steal=True,
                data_layer=None, serialize=False, delivery_latency=0.0):
    clock = SimClock()
    fed = FederatedEngine(n_shards, clock=clock, partitioner=partitioner,
                          steal=steal, data_layer=data_layer,
                          delivery_latency=delivery_latency,
                          engine_kwargs={"provenance": "summary"})
    svcs = []
    for i, eng in enumerate(fed.shards):
        dl = data_layer.layer(i) if data_layer is not None else None
        svc = _falkon_shard(clock, execs, data_layer=dl,
                            serialize=serialize)
        eng.add_site(f"falkon{i}", FalkonProvider(svc), capacity=execs,
                     data_layer=dl)
        svcs.append(svc)
    return clock, fed, svcs


# ---------------------------------------------------------------------------
# partitioning + transparent workflow
# ---------------------------------------------------------------------------

def test_hash_partitioner_is_stable_and_spreads():
    shards = [hash_partitioner(f"job#{i}", 4) for i in range(4000)]
    assert shards == [hash_partitioner(f"job#{i}", 4) for i in range(4000)]
    counts = [shards.count(s) for s in range(4)]
    assert all(700 < c < 1300 for c in counts)   # roughly uniform


def test_skewed_partitioner_is_skewed():
    part = skewed_partitioner(0.7)
    shards = [part(f"job#{i}", 4) for i in range(4000)]
    heavy = shards.count(0)
    assert 0.6 < heavy / len(shards) < 0.8
    assert set(shards) == {0, 1, 2, 3}


def test_workflow_runs_transparently_over_federation():
    """foreach / gather / dependent chains through a FederatedEngine, with
    every value crossing shards correctly."""
    clock, fed, _ = _federation(n_shards=3)
    wf = Workflow("t", fed)

    @wf.atomic(duration=0.1)
    def double(x):
        return 2 * x

    @wf.atomic(duration=0.1)
    def add(a, b):
        return a + b

    pairs = wf.foreach(list(range(20)),
                       lambda i: add(double(i), double(i + 1)))
    fed.run()
    assert pairs.resolved
    assert pairs.get() == [2 * i + 2 * (i + 1) for i in range(20)]
    assert fed.tasks_completed == 60       # 3 tasks per foreach item
    # the graph really was sharded, not funneled to one engine
    per_shard = fed.stats()["per_shard_completed"]
    assert all(c > 0 for c in per_shard) and sum(per_shard) == 60


def test_cross_shard_failure_propagates():
    clock, fed, _ = _federation(n_shards=2,
                                partitioner=lambda key, n:
                                0 if key.startswith("boom") else 1)

    def boom():
        raise RuntimeError("upstream died")

    bad = fed.submit("boom", boom, duration=0.1)
    child = fed.submit("child", None, [bad], duration=0.1)  # other shard
    fed.run()
    assert bad.failed and child.failed
    assert fed.tasks_failed == 2


def test_custom_partitioner_controls_placement():
    clock, fed, _ = _federation(n_shards=2, steal=False,
                                partitioner=lambda key, n: 1)
    outs = [fed.submit(f"t{i}", None, duration=0.1) for i in range(10)]
    fed.run()
    assert all(o.resolved for o in outs)
    assert fed.stats()["per_shard_completed"] == [0, 10]


# ---------------------------------------------------------------------------
# mailbox
# ---------------------------------------------------------------------------

def test_mailbox_coalesces_deliveries():
    """A wide fan-out consuming one cross-shard future must not cost one
    clock event per edge: one proxy per (future, shard), one flush per
    delivery window."""
    clock, fed, _ = _federation(n_shards=2, steal=False,
                                partitioner=lambda key, n:
                                0 if key.startswith("src") else 1)
    src = fed.submit("src", None, duration=1.0)
    outs = [fed.submit(f"w{i}", None, [src], duration=0.1)
            for i in range(64)]
    fed.run()
    assert all(o.resolved for o in outs)
    mb = fed.mailboxes[1]
    # 64 consumers share one proxy -> one message, one flush
    assert fed.cross_shard_edges == 1
    assert mb.messages == 1 and mb.flushes == 1


def test_mailbox_delivery_latency_delays_consumers():
    def span(latency):
        clock, fed, _ = _federation(n_shards=2, steal=False,
                                    delivery_latency=latency,
                                    partitioner=lambda key, n:
                                    0 if key.startswith("a") else 1)
        b = fed.submit("b", None, [fed.submit("a", None, duration=1.0)],
                       duration=1.0)
        fed.run()
        assert b.resolved
        return clock.now()

    assert span(5.0) - span(0.0) == pytest.approx(5.0)


def test_mailbox_late_window_message_waits_full_latency():
    """A message posted while an earlier flush window is open must still
    wait its own full latency, not ride the first message's event."""
    from repro.core.federation import Mailbox
    from repro.core.futures import DataFuture, resolved

    clock = SimClock()
    mb = Mailbox(clock, shard_id=0, latency=5.0)
    p1, p2 = DataFuture("p1"), DataFuture("p2")
    delivered = {}
    p1.on_done(lambda f: delivered.setdefault("p1", clock.now()))
    p2.on_done(lambda f: delivered.setdefault("p2", clock.now()))
    mb.post(p1, resolved(1))                      # t=0 -> due t=5
    clock.schedule(4.9, lambda: mb.post(p2, resolved(2)))  # due t=9.9
    clock.run()
    assert delivered["p1"] == pytest.approx(5.0)
    assert delivered["p2"] == pytest.approx(9.9)
    assert p1.get() == 1 and p2.get() == 2


def test_gather_joins_pay_delivery_latency():
    """Workflow-combinator futures (gather et al.) are driver-owned: a
    task consuming one on any shard still crosses the modeled transport,
    so high-fan-in joins cannot sidestep delivery latency."""
    def span(latency):
        clock, fed, _ = _federation(n_shards=2, steal=False,
                                    delivery_latency=latency)
        wf = Workflow("t", fed)
        wide = [fed.submit(f"w{i}", None, duration=1.0) for i in range(8)]
        g = wf.gather(wide)
        post = fed.submit("post", None, [g], duration=1.0)
        fed.run()
        assert post.resolved
        return clock.now()

    # one driver->shard hop for the gather join (the wide tasks are roots)
    assert span(3.0) - span(0.0) == pytest.approx(3.0)


def test_ownership_map_stays_bounded():
    """Owner bookkeeping is dropped as futures resolve — bounded by
    in-flight futures, not workflow size."""
    clock, fed, _ = _federation(n_shards=2)
    f = fed.submit("t0", None, duration=0.1)
    for i in range(1, 200):
        f = fed.submit(f"t{i}", None, [f], duration=0.1)
    fed.run()
    assert f.resolved
    assert len(fed._owner) == 0
    assert len(fed._proxies) == 0


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------

def _skewed_run(steal, n=800, execs=4):
    clock, fed, svcs = _federation(n_shards=4, execs=execs,
                                   partitioner=skewed_partitioner(0.8),
                                   steal=steal)
    wf = Workflow("t", fed)
    out = wf.gather([fed.submit(f"job{i}", None, duration=1.0)
                     for i in range(n)])
    fed.run()
    assert out.resolved and fed.tasks_completed == n
    return clock, fed, svcs


def test_stealing_bounds_idle_fraction_under_skew():
    clock_ns, fed_ns, svcs_ns = _skewed_run(steal=False)
    clock_st, fed_st, svcs_st = _skewed_run(steal=True)
    st = fed_st.metrics()["stealer"]
    assert st["tasks_stolen"] > 0 and st["steals"] > 0
    # steal-half batches, not per-task events
    assert st["steals"] <= st["tasks_stolen"]
    assert clock_st.now() < clock_ns.now() * 0.6
    # every shard did real work once stealing is on
    per_shard = fed_st.stats()["per_shard_completed"]
    assert min(per_shard) > 0.5 * max(per_shard)
    assert min(fed_ns.stats()["per_shard_completed"]) < \
        0.2 * max(fed_ns.stats()["per_shard_completed"])


def test_steal_batches_are_bounded():
    clock, fed, _ = _federation(n_shards=2, execs=2,
                                partitioner=lambda key, n: 0)
    fed.stealer.max_batch = 8
    outs = [fed.submit(f"j{i}", None, duration=1.0) for i in range(200)]
    fed.run()
    assert all(o.resolved for o in outs)
    st = fed.stealer
    assert st.tasks_stolen > 0
    assert st.batch_stat.peak <= 8


def test_stealer_respects_app_validity():
    """A thief whose sites cannot run an app must not receive its tasks."""
    clock = SimClock()
    fed = FederatedEngine(2, clock=clock, partitioner=lambda key, n: 0,
                          engine_kwargs={"provenance": "summary"})
    fed.shards[0].add_site("s0", LocalProvider(clock, 2), capacity=2,
                           apps={"special"})
    fed.shards[1].add_site("s1", LocalProvider(clock, 2), capacity=2,
                           apps={"other"})
    outs = [fed.submit(f"j{i}", None, duration=1.0, app="special")
            for i in range(40)]
    fed.run()
    assert all(o.resolved for o in outs)
    assert fed.stats()["per_shard_completed"] == [40, 0]
    assert fed.stealer.tasks_stolen == 0


def test_steal_disabled_is_partition_only():
    clock, fed, _ = _federation(n_shards=2, steal=False,
                                partitioner=lambda key, n: 0)
    outs = [fed.submit(f"j{i}", None, duration=1.0) for i in range(50)]
    fed.run()
    assert all(o.resolved for o in outs)
    assert fed.stealer is None
    assert fed.stats()["per_shard_completed"] == [50, 0]


# ---------------------------------------------------------------------------
# sharded data layer
# ---------------------------------------------------------------------------

def test_directory_tracks_cross_shard_holders():
    sdl = ShardedDataLayer(2, cache_capacity=1e9)
    clock, fed, svcs = _federation(n_shards=2, data_layer=sdl, steal=False,
                                   partitioner=lambda key, n:
                                   0 if key.startswith("a") else 1)
    f0 = sdl.shared.file("x.dat", 10e6)
    a = fed.submit("a", None, duration=0.5, inputs=(f0,))
    b = fed.submit("b", None, duration=0.5, inputs=(f0,))
    fed.run()
    assert a.resolved and b.resolved
    assert sdl.directory.shards_holding("x.dat") == frozenset({0, 1})
    assert sdl.layer(0).holds("x.dat") and sdl.layer(1).holds("x.dat")
    assert len(sdl.directory) == 1
    m = sdl.metrics()
    assert m["misses"] == 2 and m["directory_objects"] == 1


def test_restage_estimate_prices_cross_shard_migration():
    sdl = ShardedDataLayer(2, cache_capacity=1e9)
    x, y = DataObject("x.dat", 10e6), DataObject("y.dat", 5e6)
    # fabricate directory state: shard 0 holds both, shard 1 holds y
    sdl.directory.add("x.dat", 0)
    sdl.directory.add("y.dat", 0)
    sdl.directory.add("y.dat", 1)
    assert sdl.restage_estimate((x, y), 0, 1) == 10e6   # x must restage
    assert sdl.restage_estimate((x, y), 1, 0) == 0.0    # 0 already holds
    assert sdl.restage_estimate((x, y), 0, 0) == 0.0    # no migration


def test_stolen_tasks_restage_in_new_shard():
    """After a warm round, stolen tasks re-route to holders in the thief
    shard or stage replicas there — and the stealer's restage metrics are
    bounded StreamStat summaries, not per-task logs."""
    sdl = ShardedDataLayer(4, cache_capacity=200e6, park_patience=8.0)
    clock, fed, svcs = _federation(n_shards=4, execs=4, data_layer=sdl,
                                   partitioner=skewed_partitioner(0.8))
    wf = Workflow("t", fed)
    archives = [sdl.shared.file(f"m{i}.arc", 100e6) for i in range(32)]
    analyze = wf.sim_proc("analyze", duration=1.0,
                          inputs=lambda m, *_: (archives[m],))
    barrier = None
    for _ in range(3):
        futs = [analyze(j % 32) if barrier is None
                else analyze(j % 32, barrier) for j in range(256)]
        barrier = wf.gather(futs)
    fed.run()
    assert barrier.resolved
    st = fed.metrics()["stealer"]
    assert st["tasks_stolen"] > 0
    assert st["restage_bytes_est"] > 0.0
    # bounded metrics: fixed-size summaries regardless of task count
    assert len(fed.stealer.restage_stat.sample) < fed.stealer.restage_stat.cap
    assert st["restage_per_batch"]["count"] == st["steals"]
    # work actually diffused into thief shards' caches
    assert len(sdl.directory.shards_holding("m0.arc")) >= 2


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def _deterministic_probe():
    sdl = ShardedDataLayer(4, cache_capacity=400e6)
    clock, fed, svcs = _federation(n_shards=4, execs=4, data_layer=sdl,
                                   partitioner=skewed_partitioner(0.7))
    wf = Workflow("t", fed)
    files = [sdl.shared.file(f"f{i}.dat", 50e6) for i in range(8)]
    proc = wf.sim_proc("p", duration=0.5, inputs=lambda i: (files[i % 8],))
    out = wf.foreach(list(range(400)), lambda i: proc(i))
    fed.run()
    assert out.resolved
    m = fed.metrics()
    return (clock.now(), fed.stats()["per_shard_completed"],
            m["stealer"]["tasks_stolen"], m["stealer"]["steals"],
            m["data"]["bytes_staged"], m["cross_shard_edges"],
            [sorted(e.cache.objects) for svc in svcs
             for e in svc.executors])


def test_federation_is_deterministic_under_simclock():
    assert _deterministic_probe() == _deterministic_probe()


# ---------------------------------------------------------------------------
# serialized dispatch (the dispatcher ceiling federation exists for)
# ---------------------------------------------------------------------------

def test_serialized_dispatch_caps_service_throughput():
    def makespan(serialize):
        clock = SimClock()
        svc = _falkon_shard(clock, execs=64, alloc=1.0,
                            serialize=serialize)
        eng = Engine(clock, provenance="summary")
        eng.add_site("f", FalkonProvider(svc), capacity=64)
        outs = [eng.submit(f"t{i}", None, duration=0.0)
                for i in range(487)]
        eng.run()
        assert all(o.resolved for o in outs)
        return clock.now()

    serialized = makespan(True)
    parallel = makespan(False)
    # 487 zero-length tasks through one serialized dispatcher ~ 1 s
    # (net of the 1 s allocation latency both configurations pay)
    assert serialized - 1.0 == pytest.approx(1.0, abs=0.1)
    # default-off path: dispatch overheads overlap across executors
    assert parallel - 1.0 < (serialized - 1.0) / 4


def test_federation_beats_single_engine_when_dispatch_bound():
    n = 2000

    def single():
        clock = SimClock()
        svc = _falkon_shard(clock, execs=256, alloc=1.0, serialize=True)
        eng = Engine(clock, provenance="summary")
        eng.add_site("f", FalkonProvider(svc), capacity=256)
        wf = Workflow("t", eng)
        out = wf.gather([eng.submit(f"t{i}", None, duration=0.1)
                         for i in range(n)])
        eng.run()
        assert out.resolved
        return clock.now()

    def federated():
        clock, fed, _ = _federation(n_shards=4, execs=64, serialize=True)
        wf = Workflow("t", fed)
        out = wf.gather([fed.submit(f"t{i}", None, duration=0.1)
                         for i in range(n)])
        fed.run()
        assert out.resolved
        return clock.now()

    assert single() / federated() >= 1.5


# ---------------------------------------------------------------------------
# process-boundary contracts (DESIGN.md §14)
# ---------------------------------------------------------------------------
# Every message a ProcessFederation ships over a pipe/socket must survive
# pickle round-trips, the in-process QueueTransport must count sends
# correctly under producer-thread contention, StreamStat snapshots must
# merge losslessly (child pool telemetry folds into the driver), and the
# directory victim policy must prefer victims whose in-flight inputs are
# cheap to restage.

import pickle
import threading

from hypothesis import given, settings, strategies as st

from repro.core import (QueueTransport, RealClock, StreamStat, TaskFailure)
from repro.core.procfed import Ref, body_scale


def _sample_envelope(fid=7):
    # the submit/stolen task envelope: (fid, name, fn, args, duration,
    # app, key, ((input name, size), ...)) — args may embed Refs
    return (fid, "analyze", body_scale, [Ref(3), 2.0], 0.1, None,
            "an_m0_k1", (("arch.tar", 4e6),))


BOUNDARY_MESSAGES = [
    # parent -> child
    ("submit", [_sample_envelope()]),
    ("resolve", [(3, True, 41.0),
                 (4, False, TaskFailure("boom", kind="host", latency=0.2))]),
    ("steal", 1, 8),
    ("drop", [3, 4]),
    ("shutdown",),
    # child -> parent
    ("ready", 1),
    ("done", [(7, True, {"x": 1}), (8, False, ValueError("bad"))], 2, 1),
    ("dir", [("add", "arch.tar"), ("drop", "old.tar")]),
    ("stolen", 5, [_sample_envelope(9)], 4),
    ("load", 3, 2),
    ("stats", {"tasks_run": 5, "io_s": StreamStat(cap=16).snapshot()}),
]


@pytest.mark.parametrize("msg", BOUNDARY_MESSAGES, ids=lambda m: m[0])
def test_boundary_message_pickles(msg):
    out = pickle.loads(pickle.dumps(msg))
    assert out[0] == msg[0]
    if msg[0] in ("submit", "stolen"):
        env = (out[1] if msg[0] == "submit" else out[2])[0]
        src = (msg[1] if msg[0] == "submit" else msg[2])[0]
        assert env[0] == src[0] and env[6] == src[6]
        assert env[2] is body_scale          # fn restored by reference
        assert env[3][0] == Ref(3)           # Ref arg round-trips
        assert env[7] == src[7]
    elif msg[0] == "resolve":
        assert out[1][0] == msg[1][0]
        err = out[1][1][2]
        assert isinstance(err, TaskFailure)
        assert err.kind == "host" and err.latency == 0.2   # __reduce__
        assert str(err) == "boom"
    elif msg[0] == "done":
        assert isinstance(out[1][1][2], ValueError)
        assert out[1][0] == msg[1][0] and out[2:] == msg[2:]
    else:
        assert out == msg


def test_ref_is_pickle_stable_and_hashable():
    r = pickle.loads(pickle.dumps(Ref(42)))
    assert r == Ref(42) and hash(r) == hash(Ref(42))
    assert r != Ref(43) and "42" in repr(r)


def test_queue_transport_counts_sends_under_contention():
    """`sends` is bumped under the transport lock: 8 producer threads
    racing 50 sends each must lose none, and delivery stays coalesced
    (drains is counted per burst, not per message)."""
    clock = RealClock()
    t = QueueTransport()
    got = []
    t.bind(clock, got.extend)
    clock.hold()
    threads = [threading.Thread(
        target=lambda: [t.send(("m", i)) for i in range(50)])
        for _ in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    clock.post(clock.release)     # runs after every posted drain
    clock.run()
    assert t.sends == 400
    assert len(got) == 400
    assert 1 <= t.drains <= t.sends


@settings(max_examples=8, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e6),
                min_size=0, max_size=120),
       st.lists(st.floats(min_value=0.0, max_value=1e6),
                min_size=0, max_size=120))
def test_streamstat_merge_matches_sequential(xs, ys):
    """merge(from_snapshot(a), from_snapshot(b)) preserves the exact
    moments (count/total/peak/min) of the concatenated stream and keeps
    the reservoir bounded with in-range percentiles — the driver-side
    fold for child pool telemetry."""
    a, b = StreamStat(cap=32), StreamStat(cap=32)
    for i, v in enumerate(xs):
        a.observe(float(i), v)
    for i, v in enumerate(ys):
        b.observe(float(len(xs) + i), v)
    merged = StreamStat.from_snapshot(a.snapshot()) \
        .merge(StreamStat.from_snapshot(b.snapshot()))
    allv = xs + ys
    assert merged.count == len(allv)
    assert merged.total == pytest.approx(sum(allv))
    if allv:
        assert merged.peak == max(allv) and merged.low == min(allv)
        assert min(allv) <= merged.percentile(0.5) <= max(allv)
    assert len(merged.sample) < merged.cap


@settings(max_examples=8, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e3),
                min_size=1, max_size=80))
def test_streamstat_snapshot_roundtrip(xs):
    s = StreamStat(cap=16)
    for i, v in enumerate(xs):
        s.observe(float(i), v)
    snap = s.snapshot()
    assert StreamStat.from_snapshot(snap).snapshot() == snap


def test_directory_victim_policy_prefers_cheap_victims():
    """At comparable load, the directory policy steals from the victim
    whose sampled in-flight inputs the thief already holds; the load
    policy takes the longest queue regardless of restage cost."""
    from types import SimpleNamespace

    sdl = ShardedDataLayer(3, cache_capacity=1e9)
    x, y = DataObject("x.dat", 10e6), DataObject("y.dat", 10e6)
    sdl.directory.add("x.dat", 0)     # only the loaded victim holds x
    sdl.directory.add("y.dat", 1)
    sdl.directory.add("y.dat", 2)     # ...but the thief already holds y

    class _Queue(list):
        def peek(self, n):
            return list(self[:n])

    t_x = SimpleNamespace(inputs=(x,))
    t_y = SimpleNamespace(inputs=(y,))
    v_a = SimpleNamespace(shard_id=0, _pending=_Queue([t_x] * 10))
    v_b = SimpleNamespace(shard_id=1, _pending=_Queue([t_y] * 9))
    thief = SimpleNamespace(shard_id=2, _pending=_Queue())

    load = WorkStealer(SimClock(), min_batch=1, victim_policy="load")
    directory = WorkStealer(SimClock(), min_batch=1,
                            victim_policy="directory")
    assert load._pick_victim([v_a, v_b, thief], thief, sdl) is v_a
    assert directory._pick_victim([v_a, v_b, thief], thief, sdl) is v_b
    assert directory.metrics()["victim_policy"] == "directory"


def test_federated_engine_victim_policy_end_to_end():
    """`FederatedEngine(victim_policy="directory")` completes a skewed
    warm workload and never estimates more restage than the load policy
    on the identical (deterministic) run."""
    def probe(policy):
        sdl = ShardedDataLayer(4, cache_capacity=400e6)
        clock, fed, _ = _federation(n_shards=4, execs=4, data_layer=sdl,
                                    partitioner=skewed_partitioner(0.8))
        fed.stealer = WorkStealer(clock, victim_policy=policy)
        fed.stealer.attach(fed)
        wf = Workflow("t", fed)
        files = [sdl.shared.file(f"f{i}.dat", 50e6) for i in range(8)]
        proc = wf.sim_proc("p", duration=0.5,
                           inputs=lambda i: (files[i % 8],))
        out = wf.foreach(list(range(300)), lambda i: proc(i))
        fed.run()
        assert out.resolved
        st = fed.metrics()["stealer"]
        assert st["victim_policy"] == policy
        return st

    load = probe("load")
    directory = probe("directory")
    assert load["tasks_stolen"] > 0 and directory["tasks_stolen"] > 0
    assert directory["restage_bytes_est"] <= load["restage_bytes_est"]
