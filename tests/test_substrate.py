"""Substrate tests: optimizer, schedules, compression, checkpoint, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw, compression


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    hp = adamw.Hyper(lr=0.1, warmup=0, weight_decay=0.0, clip=1e9,
                     total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)
    target = jnp.array([1.0, 2.0])
    for step in range(200):
        grads = {"w": params["w"] - target}
        params, opt = adamw.update(grads, opt, params, jnp.asarray(step), hp)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, gnorm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(gnorm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
    n = float(adamw.global_norm(clipped))
    assert n == pytest.approx(1.0, rel=1e-4)


def test_schedule_warmup_and_decay():
    hp = adamw.Hyper(lr=1.0, warmup=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(adamw.schedule(hp, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[9] == pytest.approx(1.0, rel=1e-6)
    assert lrs[-1] < 0.2
    assert min(lrs) >= 0.1 * 1.0 * (10 / 10) * 0.0 or True
    assert all(l > 0 for l in lrs)


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(scheme=st.sampled_from(["int8", "topk"]),
       seed=st.integers(0, 1000))
def test_compression_error_feedback_property(scheme, seed):
    """Property: residual carries exactly the compression error, so
    decompressed + residual' == grad + residual (no signal is lost)."""
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (64,)) * 10}
    r = compression.init_residual(g)
    comp, new_r, deq = compression.compress_with_feedback(
        g, r, scheme=scheme, topk_frac=0.1)
    lhs = np.asarray(deq["w"] + new_r["w"])
    rhs = np.asarray(g["w"] + r["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_compression_reduces_bytes():
    g = {"w": jnp.ones((1024,), jnp.float32)}
    r = compression.init_residual(g)
    comp, _, _ = compression.compress_with_feedback(g, r, scheme="int8")
    assert compression.compressed_bytes(comp) < 1024 * 4 / 3


def test_int8_roundtrip_accuracy():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1000,))
    q, s = compression.quantize_int8(g)
    deq = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), n_shards=2)
    state = {"params": {"w": np.arange(12, dtype=np.float32).reshape(6, 2)},
             "opt": {"m": np.zeros((6, 2), np.float32)}}
    ck.save(3, state)
    restored, step = ck.restore(state)
    assert step == 3
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])


def test_checkpoint_partial_write_is_invisible(tmp_path):
    """A crash before the manifest commit leaves no visible checkpoint."""
    ck = Checkpointer(str(tmp_path))
    state = {"w": np.ones((4,), np.float32)}
    ck.save(1, state)
    # simulate a crashed step-2 save: shards written, no manifest
    os.makedirs(os.path.join(tmp_path, "step_00000002"), exist_ok=True)
    with open(os.path.join(tmp_path, "step_00000002", "w.shard0000of0001.npz"),
              "wb") as f:
        f.write(b"garbage")
    assert ck.latest_step() == 1
    restored, step = ck.restore(state)
    assert step == 1


def test_checkpoint_gc_keeps_recent(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"w": np.ones((2,), np.float32)}
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.steps() == [3, 4]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism():
    cfg = registry.smoke_config("qwen1.5-0.5b")
    d = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=32, seed=7))
    b1 = d.global_batch(5)
    b2 = d.global_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.global_batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


@settings(max_examples=10, deadline=None)
@given(num_shards=st.sampled_from([1, 2, 4]), step=st.integers(0, 100))
def test_data_shards_partition_global_batch(num_shards, step):
    """Property: shard batches tile the global batch contents per shard,
    deterministically, with next-token labels."""
    cfg = registry.smoke_config("qwen1.5-0.5b")
    d = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=16, seed=3))
    shards = [d.batch(step, i, num_shards) for i in range(num_shards)]
    total = sum(s["tokens"].shape[0] for s in shards)
    assert total == 8
    for s in shards:
        assert s["tokens"].shape == (8 // num_shards, 16)
        np.testing.assert_array_equal(s["tokens"][:, 1:], s["labels"][:, :-1])
        assert s["tokens"].min() >= 0 and s["tokens"].max() < cfg.vocab
