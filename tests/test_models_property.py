"""Property tests on model-internal invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models import attention as A
from repro.models import moe as M
from repro.models.params import init_tree
from repro.models.ssm import selective_scan
from repro.kernels import ref


def _naive_attn(q, k, v, causal, window, scale):
    """(B,S,H,D) layout dense reference."""
    out = ref.ref_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal,
                            window=window, scale=scale)
    return jnp.swapaxes(out, 1, 2)


@settings(max_examples=12, deadline=None)
@given(
    s_mult=st.integers(1, 4),
    kv_block=st.sampled_from([32, 64, 128]),
    n_super=st.integers(1, 8),
)
def test_causal_attention_blocking_invariance(s_mult, kv_block, n_super):
    """The super-row online-softmax decomposition equals dense attention for
    any blocking choice."""
    S, H, D = 64 * s_mult, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, D))
    k = jax.random.normal(ks[1], (2, S, H, D))
    v = jax.random.normal(ks[2], (2, S, H, D))
    out = A.causal_attention(q, k, v, scale=0.25, n_super=n_super,
                             kv_block=kv_block)
    exp = _naive_attn(q, k, v, True, 0, 0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(window=st.sampled_from([16, 32, 96]),
       q_block=st.sampled_from([16, 32, 64]))
def test_local_attention_banded_equals_masked_dense(window, q_block):
    S, H, D = 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, S, H, D))
    k = jax.random.normal(ks[1], (1, S, H, D))
    v = jax.random.normal(ks[2], (1, S, H, D))
    out = A.local_attention(q, k, v, scale=0.25, window=window,
                            q_block=q_block)
    exp = _naive_attn(q, k, v, True, window, 0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64, 128]))
def test_selective_scan_chunk_invariance(chunk):
    """The chunked recurrence is exact for every chunking."""
    B, S, D, N = 1, 64, 16, 4
    key = jax.random.PRNGKey(2)
    u = jax.random.normal(key, (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, D)))
    Am = -jnp.exp(jax.random.normal(key, (D, N)) * 0.5)
    Bm = jax.random.normal(key, (B, S, N))
    Cm = jax.random.normal(key, (B, S, N))
    y, h = selective_scan(u, dt, Am, Bm, Cm, chunk=chunk)
    ye, he = ref.ref_selective_scan(u, dt, Am, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               rtol=1e-4, atol=1e-4)


def test_moe_dispatch_modes_equivalent():
    """scatter vs index dispatch (§Perf D4) are numerically identical."""
    cfg0 = registry.smoke_config("deepseek-v2-236b")
    cfg1 = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, dispatch="index"))
    p = init_tree(M.moe_descs(cfg0), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg0.d_model))
    y0, a0 = M.apply_moe(cfg0, p, x)
    y1, a1 = M.apply_moe(cfg1, p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    assert float(a0) == pytest.approx(float(a1))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced routing, few tokens drop; the
    aux loss is ~1 for uniform routing."""
    cfg = registry.smoke_config("granite-moe-3b-a800m")
    p = init_tree(M.moe_descs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 128, cfg.d_model)) * 0.01
    _, aux = M.apply_moe(cfg, p, x)
    # aux_loss_weight * E * sum f*P ~ weight * ~1 for near-uniform routing
    assert 0 < float(aux) < 5 * cfg.moe.aux_loss_weight


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_train_grads_are_finite(seed):
    """Property: gradients of the full train loss are finite for random
    inputs (the classic NaN sentinel for masks/softmax/norm edge cases)."""
    from repro.models import transformer as T
    cfg = registry.smoke_config("qwen2-1.5b")
    params = init_tree(T.build_descriptors(cfg), jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 32), 0,
                              cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    grads = jax.grad(lambda p: T.forward_train(cfg, p, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(g)))
               for g in jax.tree_util.tree_leaves(grads))
