"""Unit tests for the data-diffusion layer (DESIGN.md §7):

  * eviction invariants — capacity never exceeded, LRU/LFU/size-aware
    victim ordering, eviction of pinned (in-use) objects deferred;
  * cache-aware dispatch — tasks are routed to executors already holding
    their inputs, the holder index tracks admissions/evictions, and runs
    are deterministic under `SimClock`;
  * GPFS-only mode (zero cache capacity) stages every read and stays
    locality-blind;
  * wave-coalesced batch admission — fewer clock events, same FIFO order
    and gateway rate.
"""
import pytest

from repro.core import (BatchSchedulerProvider, DataLayer, DataObject,
                        DRPConfig, Engine, ExecutorCache, FalkonConfig,
                        FalkonProvider, FalkonService, LFUPolicy, LRUPolicy,
                        SharedStore, SimClock, SizeAwarePolicy,
                        StagingCostModel, Workflow)


def _obj(name, size):
    return DataObject(name, size)


# ---------------------------------------------------------------------------
# eviction invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "lfu", "size"])
def test_capacity_never_exceeded(policy):
    cache = ExecutorCache(100.0, policy)
    for i in range(50):
        cache.admit(_obj(f"o{i}", 30.0))
        assert cache.used <= cache.capacity
        assert cache.used == sum(o.size for o in cache.objects.values())


@pytest.mark.parametrize("policy", ["lru", "lfu", "size"])
def test_object_larger_than_cache_is_bypassed(policy):
    cache = ExecutorCache(100.0, policy)
    cache.admit(_obj("small", 40.0))
    admitted, evicted = cache.admit(_obj("huge", 150.0))
    assert not admitted and evicted == []
    assert cache.contains("small") and cache.used == 40.0


def test_lru_evicts_least_recently_used():
    cache = ExecutorCache(100.0, "lru")
    for name in ("a", "b", "c"):
        cache.admit(_obj(name, 30.0))
    cache.touch("a")                       # recency now b < c < a
    _, evicted = cache.admit(_obj("d", 30.0))
    assert [o.name for o in evicted] == ["b"]
    _, evicted = cache.admit(_obj("e", 60.0))
    assert [o.name for o in evicted] == ["c", "a"]


def test_lfu_evicts_least_frequently_used():
    cache = ExecutorCache(100.0, "lfu")
    for name in ("a", "b", "c"):
        cache.admit(_obj(name, 30.0))
    for _ in range(3):
        cache.touch("a")
    cache.touch("c")
    _, evicted = cache.admit(_obj("d", 30.0))
    assert [o.name for o in evicted] == ["b"]   # freq: b=1 < c=2 < a=4
    # tie at freq 1 (d) vs freq 2 (c): d is least frequent
    _, evicted = cache.admit(_obj("e", 30.0))
    assert [o.name for o in evicted] == ["d"]


def test_size_aware_evicts_largest_first():
    cache = ExecutorCache(100.0, "size")
    cache.admit(_obj("big", 50.0))
    cache.admit(_obj("mid", 30.0))
    cache.admit(_obj("small", 15.0))
    _, evicted = cache.admit(_obj("new", 40.0))
    assert [o.name for o in evicted] == ["big"]
    assert cache.contains("mid") and cache.contains("small")


def test_size_aware_lazy_heap_handles_readmission():
    cache = ExecutorCache(100.0, "size")
    cache.admit(_obj("a", 60.0))
    cache.admit(_obj("b", 30.0))
    cache.admit(_obj("c", 60.0))           # evicts a (largest, oldest)
    assert not cache.contains("a")
    cache.admit(_obj("a", 60.0))           # re-admit: evicts c
    assert cache.contains("a") and not cache.contains("c")
    assert cache.used <= cache.capacity


@pytest.mark.parametrize("policy", ["lru", "lfu", "size"])
def test_pinned_objects_deferred_from_eviction(policy):
    cache = ExecutorCache(100.0, policy)
    cache.admit(_obj("inuse", 60.0))
    cache.pin("inuse")
    admitted, evicted = cache.admit(_obj("x", 60.0))
    assert not admitted and evicted == []  # only pinned bytes evictable
    assert cache.contains("inuse")
    cache.admit(_obj("y", 30.0))           # fits beside the pinned object
    assert cache.contains("y")
    cache.unpin("inuse")
    admitted, evicted = cache.admit(_obj("x", 60.0))
    assert admitted and "inuse" in [o.name for o in evicted]


def test_admit_does_not_gut_cache_on_infeasible_admission():
    """Feasibility is checked before evicting: an object that cannot fit
    beside the pinned bytes must not evict useful replicas on the way to
    failing."""
    cache = ExecutorCache(1000.0, "lru")
    cache.admit(_obj("pinned", 500.0))
    cache.pin("pinned")
    for i in range(6):
        cache.admit(_obj(f"warm{i}", 100.0))   # fills the unpinned half
    warm_before = [n for n in cache.objects if n.startswith("warm")]
    admitted, evicted = cache.admit(_obj("big", 600.0))
    assert not admitted and evicted == []      # infeasible: nothing gutted
    assert [n for n in cache.objects if n.startswith("warm")] == warm_before


def test_pin_refcounts():
    cache = ExecutorCache(100.0, "lru")
    cache.admit(_obj("a", 80.0))
    cache.pin("a")
    cache.pin("a")
    cache.unpin("a")
    assert cache.pinned("a")               # one pin still outstanding
    cache.unpin("a")
    assert not cache.pinned("a")


# ---------------------------------------------------------------------------
# cache-aware dispatch
# ---------------------------------------------------------------------------

def _diffusion_engine(n_exec=4, cache_mb=400.0, policy="lru",
                      alloc_latency=1.0):
    clock = SimClock()
    shared = SharedStore()
    dl = DataLayer(shared, StagingCostModel(), cache_capacity=cache_mb * 1e6,
                   policy=policy)
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=n_exec, alloc_latency=alloc_latency,
                      alloc_chunk=n_exec)), data_layer=dl)
    eng = Engine(clock, provenance="summary")
    eng.add_site("falkon", FalkonProvider(svc), capacity=n_exec)
    return clock, shared, dl, svc, eng


def _run_locality_workload(policy="lru", cache_mb=400.0):
    clock, shared, dl, svc, eng = _diffusion_engine(policy=policy,
                                                    cache_mb=cache_mb)
    wf = Workflow("t", eng)
    files = [shared.file(f"f{i}.dat", 100e6) for i in range(8)]
    proc = wf.sim_proc("analyze", duration=1.0,
                       inputs=lambda i: (files[i % 8],))
    out = wf.foreach(list(range(256)), lambda i: proc(i))
    wf.run()
    assert out.resolved
    return clock, dl, svc, eng


def test_dispatch_prefers_holders_and_hits():
    _, dl, svc, eng = _run_locality_workload()
    assert eng.tasks_completed == 256
    # 8 distinct files; each staged a bounded number of times (cold misses
    # + replicas), everything else served from executor caches
    assert dl.hits + dl.misses == 256
    assert dl.hit_rate() > 0.9
    assert dl.metrics()["indexed_objects"] == 8


def test_dispatch_is_deterministic_under_simclock():
    runs = [_run_locality_workload() for _ in range(2)]
    (c1, d1, s1, e1), (c2, d2, s2, e2) = runs
    assert c1.now() == c2.now()
    assert d1.hits == d2.hits and d1.misses == d2.misses
    assert d1.bytes_staged == d2.bytes_staged
    assert s1.dispatched == s2.dispatched
    # identical per-executor task assignment, not just aggregates
    assert [e.tasks_done for e in s1.executors] == \
        [e.tasks_done for e in s2.executors]
    assert [sorted(e.cache.objects) for e in s1.executors] == \
        [sorted(e.cache.objects) for e in s2.executors]


def test_idle_pool_stays_bounded_under_affinity_dispatch():
    """Claiming idle holders off-deque must not grow the idle pool: an
    executor keeps at most one live entry (regression for the stale-entry
    leak under affinity-heavy steady state)."""
    clock, shared, dl, svc, eng = _diffusion_engine(n_exec=2)
    wf = Workflow("t", eng)
    f0 = shared.file("hot.dat", 10e6)
    proc = wf.sim_proc("read", duration=1.0, inputs=lambda *_: (f0,))
    out = proc()
    for _ in range(500):
        out = proc(out)                # serial chain, same input every time
    eng.run()
    assert out.resolved
    assert len(svc._idle) <= len(svc.executors)


def test_hot_shared_input_does_not_serialize_wide_fanout():
    """Wait-vs-stage: compute-heavy tasks sharing one hot input must
    replicate across idle executors instead of all parking behind the
    first holder (regression: 100 x 10s tasks once took 24x the
    locality-blind makespan)."""
    def makespan(cache_mb):
        clock, shared, dl, svc, eng = _diffusion_engine(
            n_exec=16, cache_mb=cache_mb)
        wf = Workflow("t", eng)
        hot = shared.file("hot.dat", 100e6)
        proc = wf.sim_proc("crunch", duration=10.0, inputs=lambda i: (hot,))
        out = wf.foreach(list(range(64)), lambda i: proc(i))
        wf.run()
        assert out.resolved
        return clock.now(), dl

    t_aware, dl = makespan(400.0)
    t_blind, _ = makespan(0.0)
    # staging 100 MB is cheap next to 10 s of compute: the whole pool must
    # be used (64 tasks / 16 executors ~ 4 rounds), not one holder
    assert t_aware <= t_blind * 1.5
    assert dl.misses > 1                   # replicas were staged


def test_holder_index_tracks_evictions():
    clock, shared, dl, svc, eng = _diffusion_engine(
        n_exec=1, cache_mb=250.0)   # holds db-less: 2 x 100MB files
    wf = Workflow("t", eng)
    files = [shared.file(f"f{i}.dat", 100e6) for i in range(4)]
    proc = wf.sim_proc("scan", duration=1.0,
                       inputs=lambda i, *_: (files[i],))
    # serial chain so the single executor churns through all four files
    f = proc(0)
    for i in (1, 2, 3, 0, 1):
        f = proc(i, f)
    eng.run()
    assert f.resolved
    e = svc.executors[0]
    # index contains exactly the objects currently cached on the executor
    held = {name for name, holders in dl._holders.items()
            if e.id in holders}
    assert held == set(e.cache.objects)
    assert e.cache.used <= e.cache.capacity
    assert e.cache.evictions > 0


def test_gpfs_only_mode_stages_everything():
    clock, shared, dl, svc, eng = _diffusion_engine(cache_mb=0.0)
    wf = Workflow("t", eng)
    f = shared.file("x.dat", 100e6)
    proc = wf.sim_proc("read", duration=0.5, inputs=lambda i: (f,))
    out = wf.foreach(list(range(32)), lambda i: proc(i))
    wf.run()
    assert out.resolved
    assert dl.hits == 0 and dl.misses == 32
    assert dl.bytes_staged == 32 * 100e6
    assert dl.metrics()["indexed_objects"] == 0
    assert shared.reads == 32 and shared.readers == 0  # all reads released


def test_staging_costs_extend_makespan():
    def makespan(size):
        clock, shared, dl, svc, eng = _diffusion_engine(n_exec=1,
                                                        cache_mb=0.0)
        wf = Workflow("t", eng)
        f = shared.file("x.dat", size)
        proc = wf.sim_proc("read", duration=1.0, inputs=lambda: (f,))
        out = proc()
        wf.run()
        assert out.resolved
        return clock.now()

    small, big = makespan(1e6), makespan(500e6)
    assert big > small  # staging 500 MB costs more than 1 MB
    # 500 MB at the 500 MB/s single-reader bandwidth ~ 1 s extra
    assert big - small == pytest.approx(499e6 / 500e6, rel=0.05)


def test_data_layer_metrics_are_bounded():
    _, dl, svc, eng = _run_locality_workload()
    m = dl.metrics()
    assert m["hits"] == dl.hits and m["misses"] == dl.misses
    assert 0.0 <= m["hit_rate"] <= 1.0
    assert len(dl.staged_stat.sample) < dl.staged_stat.cap
    assert len(dl.hit_stat.sample) < dl.hit_stat.cap
    assert m["staged_per_task"]["count"] == dl.hits + dl.misses \
        or m["staged_per_task"]["count"] <= dl.hits + dl.misses
    # falkon metrics surface the data section only when a layer is attached
    assert "data" in svc.metrics()
    clock = SimClock()
    plain = FalkonService(clock)
    assert "data" not in plain.metrics()


def test_locality_blind_service_unchanged_without_data_layer():
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=4, alloc_latency=1.0, alloc_chunk=4)))
    eng = Engine(clock, provenance="summary")
    eng.add_site("f", FalkonProvider(svc), capacity=4)
    obj = DataObject("x.dat", 1e6)
    outs = [eng.submit(f"t{i}", None, duration=1.0, inputs=(obj,))
            for i in range(16)]
    eng.run()
    assert all(o.resolved for o in outs)
    # inputs are carried on the task but ignored: no staging time was added
    assert clock.now() == pytest.approx(1.0 + 4 * 1.0 + 4 / 487.0, rel=0.01)


def test_clustering_bundles_carry_union_of_inputs():
    """ClusteringProvider composes with the data layer: a bundle stages the
    union of its members' declared inputs (not silently none)."""
    from repro.core import ClusteringProvider
    clock, shared, dl, svc, eng = _diffusion_engine(n_exec=2)
    prov = ClusteringProvider(clock, FalkonProvider(svc), window=0.5,
                              bundle_size=4)
    eng.balancer.sites[0].provider = prov
    f0 = shared.file("a.dat", 10e6)
    f1 = shared.file("b.dat", 20e6)
    outs = [eng.submit(f"t{i}", None, duration=1.0,
                       inputs=(f0,) if i % 2 else (f0, f1))
            for i in range(8)]
    eng.run()
    assert all(o.resolved for o in outs)
    # two bundles, union inputs {a, b}: staged once (cold bundle), served
    # from cache for the second bundle (affinity routing)
    assert dl.misses == 2 and dl.hits == 2
    assert dl.bytes_staged == 30e6
    assert shared.reads == dl.misses


# ---------------------------------------------------------------------------
# wave-coalesced batch admission
# ---------------------------------------------------------------------------

def test_batch_admission_coalesces_events_under_backlog():
    clock = SimClock()
    prov = BatchSchedulerProvider(clock, nodes=4, submit_rate=10.0,
                                  sched_latency=30.0)
    done = []
    from repro.core.futures import DataFuture
    from repro.core.task import Task
    n = 300
    for i in range(n):
        t = Task(f"t{i}", None, [], DataFuture(), 1.0, None,
                 retries=0, durable=False, key="")
        prov.submit(t, lambda ok, v, e, i=i: done.append(i))
    clock.run()
    assert done == list(range(n))          # FIFO preserved
    # 300 jobs at 10 jobs/s gateway, 30 s scheduler cycle, 3.75 s admit
    # window: ~37 jobs share each admission event instead of one per job
    assert prov.admission_events <= 10
    assert prov.admission_events >= 2


def test_batch_wave_timing_matches_per_job_bounds():
    """Each job is admitted no earlier than its per-job admission time
    (gateway slot + sched_latency, the seed's model) and at most
    `admit_window` later — so serial-gateway pacing is preserved."""
    clock = SimClock()
    prov = BatchSchedulerProvider(clock, nodes=1000, submit_rate=2.0,
                                  sched_latency=20.0)
    from repro.core.futures import DataFuture
    from repro.core.task import Task
    tasks = []
    for i in range(50):
        t = Task(f"t{i}", None, [], DataFuture(), 0.0, None,
                 retries=0, durable=False, key="")
        tasks.append(t)
        prov.submit(t, lambda ok, v, e: None)
    clock.run()
    for i, t in enumerate(tasks):
        admit = i * 0.5 + 20.0
        assert t.start_time >= admit - 1e-9
        assert t.start_time <= admit + prov.admit_window + 1e-9


def test_batch_wave_preserves_gateway_rate_distinction():
    """Two providers differing only in gateway rate must still produce
    different makespans (the Fig 6/12 PBS-vs-Condor distinction) — wave
    quantization must not collapse the serial throttle."""
    def makespan(rate):
        clock = SimClock()
        prov = BatchSchedulerProvider(clock, nodes=1000, submit_rate=rate,
                                      sched_latency=133.0)
        from repro.core.futures import DataFuture
        from repro.core.task import Task
        for i in range(64):
            t = Task(f"t{i}", None, [], DataFuture(), 1.0, None,
                     retries=0, durable=False, key="")
            prov.submit(t, lambda ok, v, e: None)
        clock.run()
        return clock.now()

    pbs, condor = makespan(1.0), makespan(0.5)
    # last job clears the gateway at ~63 s vs ~126 s; both + 133 s latency
    assert condor - pbs == pytest.approx(63.0, abs=2 * 133.0 / 8)
    assert condor > pbs


def test_batch_zero_latency_is_exact_per_job():
    clock = SimClock()
    prov = BatchSchedulerProvider(clock, nodes=4, submit_rate=1e9,
                                  sched_latency=0.0)
    from repro.core.futures import DataFuture
    from repro.core.task import Task
    done = []
    for i in range(16):
        t = Task(f"t{i}", None, [], DataFuture(), 1.0, None,
                 retries=0, durable=False, key="")
        prov.submit(t, lambda ok, v, e, i=i: done.append(i))
    clock.run()
    assert done == list(range(16))
    assert prov.admission_events == 16     # singleton waves
    assert clock.now() == pytest.approx(4.0)
